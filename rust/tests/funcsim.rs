//! Functional-datapath model vs the PJRT-executed HLO artifact.
//!
//! The FuncSim executes the pruned ViT through the *hardware's* data
//! structures (Fig. 5 block-sparse headers, bitonic TDHM routing, narrow
//! MLP); PJRT executes the AOT-lowered jax graph. Same weights, same
//! input -> the logits must agree. This pins the hardware datapath to
//! the algorithm spec end-to-end.
//!
//! Needs the PJRT runtime (`--features pjrt`) AND trained artifacts:
//! point VITFPGA_ARTIFACTS at the output of `make artifacts`. Without
//! either, the whole suite skips (with a message) instead of failing.

#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use vitfpga::funcsim::{FuncSim, Precision};
use vitfpga::runtime::{weights, Engine};
use vitfpga::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = match std::env::var("VITFPGA_ARTIFACTS") {
        Ok(d) => PathBuf::from(d),
        Err(_) => Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    };
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: no manifest.json under {} (run `make artifacts` and/or set \
             VITFPGA_ARTIFACTS)",
            dir.display()
        );
        None
    }
}

fn image_geom(model: &str) -> (usize, usize, usize) {
    match model {
        "test-tiny" => (32, 8, 3),
        _ => (224, 16, 3),
    }
}

fn compare(dir: &Path, variant: &str, tol: f32) {
    let engine = Engine::new(dir).expect("engine");
    let entry = engine.manifest.find_matching(variant).expect("variant").clone();
    let pjrt = engine.load(&entry.name).expect("load");
    let fs = FuncSim::load(
        &dir.join(&entry.weights_file),
        &dir.join(&entry.structure_file),
        image_geom(&entry.model),
        Precision::F32,
    )
    .expect("funcsim");

    let mut rng = Rng::new(11);
    let per_image = pjrt.input_elems / pjrt.batch();
    let img: Vec<f32> = (0..per_image).map(|_| rng.normal()).collect();
    // PJRT artifact has a static batch; replicate the image.
    let flat: Vec<f32> = (0..pjrt.batch()).flat_map(|_| img.iter().copied()).collect();
    let want = pjrt.infer(&flat).expect("pjrt infer");
    let got = fs.forward(&img).expect("funcsim forward");
    let classes = pjrt.num_classes();
    let mut max_err = 0.0f32;
    let mut max_mag = 0.0f32;
    for (a, b) in got.iter().zip(&want[..classes]) {
        max_err = max_err.max((a - b).abs());
        max_mag = max_mag.max(b.abs());
    }
    assert!(
        max_err < tol * max_mag.max(1.0),
        "{}: funcsim-vs-pjrt max err {} (mag {})",
        entry.name,
        max_err,
        max_mag
    );
}

#[test]
fn funcsim_matches_pjrt_tiny_pruned() {
    let Some(dir) = artifacts_dir() else { return };
    compare(&dir, "test-tiny_b8_rb0.7_rt0.7_bs1", 2e-3);
}

#[test]
fn funcsim_matches_pjrt_tiny_dense() {
    let Some(dir) = artifacts_dir() else { return };
    compare(&dir, "test-tiny_b8_rb1_rt1_bs1", 2e-3);
}

#[test]
fn funcsim_matches_pjrt_deit_small() {
    let Some(dir) = artifacts_dir() else { return };
    compare(&dir, "deit-small_b16_rb0.5_rt0.5_bs1", 5e-3);
}

#[test]
fn int16_datapath_precision_characterized() {
    // Section VI uses int16: the quantized datapath must track the f32
    // path closely (this is the accuracy-impact characterization that
    // lets the paper evaluate accuracy in fp and latency in int16).
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    let entry = engine
        .manifest
        .find_matching("test-tiny_b8_rb0.7_rt0.7_bs1")
        .expect("variant")
        .clone();
    let geom = image_geom(&entry.model);
    let f32_sim = FuncSim::load(
        &dir.join(&entry.weights_file),
        &dir.join(&entry.structure_file),
        geom,
        Precision::F32,
    )
    .unwrap();
    let i16_sim = FuncSim::load(
        &dir.join(&entry.weights_file),
        &dir.join(&entry.structure_file),
        geom,
        Precision::Int16,
    )
    .unwrap();
    let mut rng = Rng::new(3);
    let mut agree = 0;
    let total = 8;
    for _ in 0..total {
        let img: Vec<f32> = (0..geom.0 * geom.0 * geom.2).map(|_| rng.normal()).collect();
        let a = f32_sim.forward(&img).unwrap();
        let b = i16_sim.forward(&img).unwrap();
        let argmax = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .unwrap()
                .0
        };
        if argmax(&a) == argmax(&b) {
            agree += 1;
        }
        // logits stay close in relative terms
        let mag = a.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-6);
        let err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err / mag < 0.2, "int16 rel err {}", err / mag);
    }
    assert!(agree >= total - 1, "int16 changed {}/{} predictions", total - agree, total);
}

#[test]
fn funcsim_detects_weight_corruption() {
    // Failure injection: corrupting the weights file must either fail to
    // parse or produce different logits — the check pipeline is not
    // vacuous.
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    let entry = engine
        .manifest
        .find_matching("test-tiny_b8_rb0.7_rt0.7_bs1")
        .expect("variant")
        .clone();
    let geom = image_geom(&entry.model);
    let wpath = dir.join(&entry.weights_file);
    let ts = weights::read_weights(&wpath).unwrap();
    let st = vitfpga::sim::ModelStructure::load(&dir.join(&entry.structure_file)).unwrap();
    let mut corrupted = ts.clone();
    // flip a weight in the first encoder's qkv
    let t = corrupted.iter_mut().find(|t| t.name.contains("w_qkv")).unwrap();
    let nz = t.data.iter().position(|&x| x != 0.0).unwrap();
    t.data[nz] += 1.0;

    // `from_tensors` takes the tensors by value (weight loads move the
    // payloads instead of copying them), so clone-and-mutate first.
    let clean = FuncSim::from_tensors(ts, st.clone(), geom, Precision::F32).unwrap();
    let dirty = FuncSim::from_tensors(corrupted, st, geom, Precision::F32).unwrap();

    let mut rng = Rng::new(4);
    let img: Vec<f32> = (0..geom.0 * geom.0 * geom.2).map(|_| rng.normal()).collect();
    let a = clean.forward(&img).unwrap();
    let b = dirty.forward(&img).unwrap();
    let diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(diff > 1e-6, "corruption was not observable");
}
