//! Battery for the self-hosted static analyzer (`vitfpga lint`).
//!
//! Three layers:
//!
//! 1. **Fixture battery** — one known-bad snippet per invariant family,
//!    asserting the exact finding code each produces, plus the annotated
//!    twin asserting the escape hatch works. This is what pins "exits
//!    nonzero on each violation class".
//! 2. **Lexer edge cases at the analyzer level** — raw strings, nested
//!    comments, byte strings and lifetimes flowing through the full
//!    check pipeline (the lexer's own unit tests cover tokenization;
//!    here we assert no *findings* leak out of tricky surface forms).
//! 3. **Live-tree self-check** — the analyzer runs over this repo's
//!    actual `src/`, `tests/` and `benches/` and must come back with
//!    zero findings. This is the bit-exactness of the lint itself: the
//!    tree the CI job checks is the tree these tests pin.

use std::path::PathBuf;

use vitfpga::analysis::{lint_source, run, FileOutcome, LintConfig};

fn lint(file: &str, src: &str) -> FileOutcome {
    lint_source(file, src, &LintConfig::default())
}

fn codes(o: &FileOutcome) -> Vec<String> {
    o.findings.iter().map(|f| f.code.clone()).collect()
}

// ---------------------------------------------------------------------------
// 1. Fixture battery: each invariant family fires, and its escape hatch
//    silences it.
// ---------------------------------------------------------------------------

#[test]
fn lex_unbalanced_delimiters_fire_lex001() {
    let o = lint("src/x.rs", "fn f() { let v = (1, 2; }\n");
    assert!(codes(&o).contains(&"LEX001".to_string()), "{:?}", o.findings);
    let o = lint("src/x.rs", "fn f() {}\n]\n");
    assert_eq!(codes(&o), vec!["LEX001"]);
    // Unterminated block comment and string.
    assert_eq!(codes(&lint("src/x.rs", "/* never closed\n")), vec!["LEX001"]);
    assert!(codes(&lint("src/x.rs", "fn f() { let s = \"oops; }\n"))
        .contains(&"LEX001".to_string()));
}

#[test]
fn unsafe_without_safety_fires_uns_family() {
    assert_eq!(codes(&lint("src/x.rs", "fn f() { unsafe { g() } }\n")), vec!["UNS001"]);
    assert_eq!(codes(&lint("src/x.rs", "unsafe fn f() {}\n")), vec!["UNS002"]);
    assert_eq!(codes(&lint("src/x.rs", "unsafe impl Send for X {}\n")), vec!["UNS003"]);
    // Documented forms pass.
    let ok = "\
// SAFETY: g upholds its contract here.
fn f() { unsafe { g() } }
/// # Safety
/// Caller must pin the buffer.
unsafe fn h() {}
// SAFETY: X owns its pointer exclusively.
unsafe impl Send for X {}
";
    assert!(codes(&lint("src/x.rs", ok)).is_empty());
}

#[test]
fn hot_path_panics_fire_hp_family() {
    let hot = "src/funcsim/kernels.rs"; // designated hot file
    assert_eq!(codes(&lint(hot, "fn f(x: Option<i32>) -> i32 { x.unwrap() }\n")), vec!["HP001"]);
    assert_eq!(
        codes(&lint(hot, "fn f(x: Option<i32>) -> i32 { x.expect(\"set\") }\n")),
        vec!["HP002"]
    );
    assert_eq!(codes(&lint(hot, "fn f() { panic!(\"boom\") }\n")), vec!["HP003"]);
    assert_eq!(codes(&lint(hot, "fn f() { unreachable!() }\n")), vec!["HP003"]);
    assert_eq!(codes(&lint(hot, "fn f(n: usize) { assert!(n > 0); }\n")), vec!["HP004"]);
    assert_eq!(codes(&lint(hot, "fn f(v: &[f32]) -> f32 { v[0] }\n")), vec!["HP005"]);
    // The same code in a non-hot module is not flagged...
    assert!(codes(&lint("src/bench_harness.rs", "fn f(v: &[f32]) -> f32 { v[0] }\n")).is_empty());
    // ...nor under #[cfg(test)] in the hot file itself.
    let tests = "#[cfg(test)]\nmod tests {\n    fn f(v: &[f32]) -> f32 { v[0].max(v.len() as f32) }\n    #[test]\n    fn t() { assert!(f(&[1.0]) > 0.0); }\n}\n";
    assert!(codes(&lint(hot, tests)).is_empty(), "{:?}", lint(hot, tests).findings);
    // debug_assert is the sanctioned hot-path form.
    assert!(codes(&lint(hot, "fn f(n: usize) { debug_assert!(n > 0); }\n")).is_empty());
}

#[test]
fn hot_region_allocation_fires_ha001() {
    let src = "\
fn f(n: usize, xs: &[u8]) -> usize {
    // lint: hot
    let v = vec![0u8; n];
    let w = xs.to_vec();
    let s = format!(\"{}\", n);
    let b = Box::new(n);
    // lint: endhot
    let after = Vec::new();
    v.len() + w.len() + s.len() + *b + after.len()
}
";
    let o = lint("src/obs/mod.rs", src);
    assert_eq!(codes(&o), vec!["HA001", "HA001", "HA001", "HA001"], "{:?}", o.findings);
    // Box::new is matched via the Vec/Box/String::new family.
    let o = lint("src/obs/mod.rs", "fn f() {\n    // lint: hot\n    let s = String::new();\n    // lint: endhot\n}\n");
    assert_eq!(codes(&o), vec!["HA001"]);
}

#[test]
fn atomic_ordering_fires_at_family() {
    // SeqCst without a justifying comment nearby (the file-level
    // contract comment sits more than 3 lines away, so it satisfies
    // AT003 but not AT001's proximity requirement).
    let src = "\
// ordering: contract lives here, far from the use site.
fn f(a: &AtomicU64) {
    let x = 1;
    let _ = x;
    a.store(1, Ordering::SeqCst);
}
";
    assert_eq!(codes(&lint("src/x.rs", src)), vec!["AT001"]);
    // Relaxed success ordering on a CAS.
    let src = "// ordering: contract present.\nfn f(a: &AtomicU64) { let _ = a.compare_exchange(0, 1, Ordering::Relaxed, Ordering::Relaxed); }\n";
    assert_eq!(codes(&lint("src/x.rs", src)), vec!["AT002"]);
    // fetch_update's first argument is its success ordering.
    let src = "// ordering: contract present.\nfn f(a: &AtomicU64) { let _ = a.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v + 1)); }\n";
    assert_eq!(codes(&lint("src/x.rs", src)), vec!["AT002"]);
    // Atomics with no ordering contract comment anywhere in the file.
    let src = "fn f(a: &AtomicU64) { a.store(1, Ordering::Release); }\n";
    assert_eq!(codes(&lint("src/x.rs", src)), vec!["AT003"]);
    // Properly paired + documented file is clean.
    let src = "\
// ordering: flag is store(Release)/load(Acquire); the CAS uses
// AcqRel success so the winner publishes its queue slot.
fn f(a: &AtomicU64) {
    a.store(1, Ordering::Release);
    let _ = a.load(Ordering::Acquire);
    let _ = a.compare_exchange(1, 2, Ordering::AcqRel, Ordering::Acquire);
}
";
    assert!(codes(&lint("src/x.rs", src)).is_empty());
}

#[test]
fn lock_hygiene_fires_lk_family() {
    let src = "fn f(m: &Mutex<i32>) -> i32 { *m.lock().unwrap() }\n";
    assert_eq!(codes(&lint("src/x.rs", src)), vec!["LK001"]);
    // Poison-recovering form is the sanctioned one.
    let src = "fn f(m: &Mutex<i32>) -> i32 { *m.lock().unwrap_or_else(|e| e.into_inner()) }\n";
    assert!(codes(&lint("src/x.rs", src)).is_empty());
    // Channel send while a guard is live.
    let src = "\
fn f(m: &Mutex<i32>, tx: &Sender<i32>) {
    let g = m.lock().unwrap_or_else(|e| e.into_inner());
    tx.send(*g).ok();
}
";
    assert_eq!(codes(&lint("src/x.rs", src)), vec!["LK002"]);
    // Dropping the guard first is clean.
    let src = "\
fn f(m: &Mutex<i32>, tx: &Sender<i32>) {
    let g = m.lock().unwrap_or_else(|e| e.into_inner());
    let v = *g;
    drop(g);
    tx.send(v).ok();
}
";
    assert!(codes(&lint("src/x.rs", src)).is_empty());
}

#[test]
fn annotations_require_reasons_and_match() {
    // allow without a reason is malformed.
    assert_eq!(codes(&lint("src/x.rs", "// lint: allow(index)\nfn f() {}\n")), vec!["ANN001"]);
    // Unknown mnemonic.
    assert_eq!(
        codes(&lint("src/x.rs", "// lint: allow(everything: please)\nfn f() {}\n")),
        vec!["ANN001"]
    );
    // Unmatched hot region.
    assert_eq!(codes(&lint("src/x.rs", "fn f() {}\n// lint: hot\n")), vec!["ANN002"]);
    assert_eq!(codes(&lint("src/x.rs", "// lint: endhot\nfn f() {}\n")), vec!["ANN002"]);
    // A valid trailing allow both silences the finding and counts it.
    let o = lint(
        "src/server/http.rs",
        "fn f(v: &[f32]) -> f32 { v[0] } // lint: allow(index: caller pins len >= 1)\n",
    );
    assert!(o.findings.is_empty(), "{:?}", o.findings);
    assert_eq!(o.suppressed, 1);
    // allow-file scopes to the whole file and stacks multiple names.
    let src = "\
// lint: allow-file(index, assert: kernel entry contracts, hardware-mirroring loops)
fn f(v: &[f32], n: usize) -> f32 { assert!(n > 0); v[n - 1] }
";
    let o = lint("src/funcsim/kernels.rs", src);
    assert!(o.findings.is_empty(), "{:?}", o.findings);
    assert_eq!(o.suppressed, 2);
}

// ---------------------------------------------------------------------------
// 2. Lexer edge cases through the full pipeline: no phantom findings.
// ---------------------------------------------------------------------------

#[test]
fn tricky_surface_forms_produce_no_findings() {
    let hot = "src/funcsim/kernels.rs";
    // Raw strings hiding panics, quotes and braces.
    let src = r####"
fn f() -> &'static str {
    r#"contains .unwrap() and panic!("x") and v[0] and { ( ["#
}
"####;
    assert!(codes(&lint(hot, src)).is_empty());
    // Nested block comments hiding an unsafe block and an assert.
    let src = "/* outer /* unsafe { } assert!(x) */ still comment */\nfn f() {}\n";
    assert!(codes(&lint(hot, src)).is_empty());
    // Lifetimes are not char literals; char literals close properly.
    let src = "fn f<'a>(x: &'a [u8]) -> char { let c = 'x'; let _ = b'\\n'; c }\n";
    assert!(codes(&lint(hot, src)).is_empty());
    // Byte strings and raw byte strings hide their contents.
    let src = "fn f() -> (&'static [u8], &'static [u8]) { (b\"unwrap()[0]\", br#\"assert!{(\"#) }\n";
    assert!(codes(&lint(hot, src)).is_empty());
    // A commented-out lock().unwrap() is invisible.
    let src = "fn f() {\n    // let g = m.lock().unwrap();\n}\n";
    assert!(codes(&lint("src/x.rs", src)).is_empty());
}

#[test]
fn string_contents_never_reach_checks() {
    let src = "fn f() -> &'static str { \"Ordering::SeqCst .lock().unwrap() unsafe {\" }\n";
    assert!(codes(&lint("src/x.rs", src)).is_empty());
}

// ---------------------------------------------------------------------------
// 3. Live-tree self-check: the analyzer over its own repository.
// ---------------------------------------------------------------------------

#[test]
fn live_tree_is_clean() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let roots: Vec<PathBuf> = ["src", "tests", "benches"]
        .iter()
        .map(|d| manifest.join(d))
        .filter(|p| p.is_dir())
        .collect();
    assert!(!roots.is_empty(), "no source roots under {}", manifest.display());
    let report = run(&roots, &LintConfig::default()).expect("lint run");
    assert!(report.files > 50, "expected the full tree, scanned {}", report.files);
    let rendered = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: {}({}) {}", f.file, f.line, f.code, f.name, f.message))
        .collect::<Vec<_>>()
        .join("\n");
    assert!(
        report.clean(),
        "the repo tree must lint clean; {} finding(s):\n{}",
        report.findings.len(),
        rendered
    );
    // The escape hatches are in active, bounded use — if this number
    // balloons, the annotations have stopped being exceptional.
    assert!(report.suppressed > 0, "expected some annotated suppressions");
}

#[test]
fn json_report_shape() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let report = run(&[manifest.join("src").join("analysis")], &LintConfig::default())
        .expect("lint run");
    let j = report.to_json();
    assert_eq!(j.get("clean").and_then(|v| v.as_bool()), Some(true));
    assert!(j.get("files").and_then(|v| v.as_usize()).unwrap_or(0) >= 3);
    assert!(j.get("findings").and_then(|v| v.as_arr()).is_some());
    // Round-trips through the repo's own JSON parser.
    let text = j.to_string_pretty();
    let back = vitfpga::util::json::Json::parse(&text).expect("valid JSON");
    assert_eq!(back.get("clean").and_then(|v| v.as_bool()), Some(true));
}
