//! Integration tests over the real AOT artifacts: PJRT round-trip
//! numerics, the coordinator under concurrent load, and the simulator
//! consuming python-exported structure files.
//!
//! These tests need the PJRT runtime (`--features pjrt`) plus the
//! artifacts from `make artifacts` (point VITFPGA_ARTIFACTS at them);
//! they skip (with a message) otherwise so `cargo test` works
//! standalone. The artifact-free serving stack is covered in
//! rust/tests/backend.rs.

#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use vitfpga::coordinator::{BatchPolicy, Coordinator};
use vitfpga::runtime::{weights, Engine};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = match std::env::var("VITFPGA_ARTIFACTS") {
        Ok(d) => PathBuf::from(d),
        Err(_) => Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    };
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: no manifest.json under {} (run `make artifacts` and/or set \
             VITFPGA_ARTIFACTS)",
            dir.display()
        );
        None
    }
}

/// Replay the python-side self-check through PJRT: logits must match.
fn check_variant_numerics(dir: &Path, name_substr: &str, tol: f32) {
    let engine = Engine::new(dir).expect("engine");
    let entry = engine
        .manifest
        .find_matching(name_substr)
        .unwrap_or_else(|| panic!("variant {} not found", name_substr))
        .clone();
    let loaded = engine.load(&entry.name).expect("load variant");
    let check_path = dir.join(format!("{}.check.bin", entry.name));
    let tensors = weights::read_weights(&check_path).expect("check file");
    assert_eq!(tensors.len(), 2);
    assert_eq!(tensors[0].name, "input");
    assert_eq!(tensors[1].name, "logits");
    let got = loaded.infer(&tensors[0].data).expect("infer");
    let want = &tensors[1].data;
    assert_eq!(got.len(), want.len());
    let mut max_err = 0.0f32;
    for (a, b) in got.iter().zip(want) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < tol,
        "{}: rust-vs-python logits max err {} > {}",
        entry.name,
        max_err,
        tol
    );
}

#[test]
fn pjrt_roundtrip_matches_python_tiny() {
    let Some(dir) = artifacts_dir() else { return };
    check_variant_numerics(&dir, "test-tiny_b8_rb0.7_rt0.7_bs1", 1e-3);
}

#[test]
fn pjrt_roundtrip_matches_python_tiny_baseline() {
    let Some(dir) = artifacts_dir() else { return };
    check_variant_numerics(&dir, "test-tiny_b8_rb1_rt1_bs1", 1e-3);
}

#[test]
fn pjrt_roundtrip_matches_python_kernel_variant() {
    // The Pallas-kernel artifact must agree with python too — proving the
    // interpret-mode kernels lower into HLO the CPU PJRT can execute.
    let Some(dir) = artifacts_dir() else { return };
    check_variant_numerics(&dir, "test-tiny_b8_rb0.7_rt0.7_bs1_kernels", 1e-3);
}

#[test]
fn pjrt_roundtrip_matches_python_deit_small() {
    let Some(dir) = artifacts_dir() else { return };
    check_variant_numerics(&dir, "deit-small_b16_rb0.5_rt0.5_bs1", 2e-3);
}

#[test]
fn kernel_and_jnp_artifacts_agree() {
    // Same weights, same input -> the kernel-path artifact and the
    // jnp-path artifact must produce identical predictions.
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    let a = engine.load("test-tiny_b8_rb0.7_rt0.7_bs1").expect("jnp variant");
    let b = engine
        .load("test-tiny_b8_rb0.7_rt0.7_bs1_kernels")
        .expect("kernel variant");
    let mut rng = vitfpga::util::rng::Rng::new(99);
    let img: Vec<f32> = (0..a.input_elems).map(|_| rng.normal()).collect();
    let la = a.infer(&img).unwrap();
    let lb = b.infer(&img).unwrap();
    let max_err = la
        .iter()
        .zip(&lb)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "kernel vs jnp artifacts differ by {}", max_err);
}

#[test]
fn batch4_variant_consistent_with_batch1() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new(&dir).expect("engine");
    let b1 = engine.load("test-tiny_b8_rb0.7_rt0.7_bs1").expect("bs1");
    let b4 = engine.load("test-tiny_b8_rb0.7_rt0.7_bs4").expect("bs4");
    let per_image = b1.input_elems;
    let mut rng = vitfpga::util::rng::Rng::new(5);
    let imgs: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..per_image).map(|_| rng.normal()).collect())
        .collect();
    let flat: Vec<f32> = imgs.iter().flatten().copied().collect();
    let batch_logits = b4.infer(&flat).unwrap();
    let classes = b4.num_classes();
    for (i, img) in imgs.iter().enumerate() {
        let single = b1.infer(img).unwrap();
        let row = &batch_logits[i * classes..(i + 1) * classes];
        let max_err = single
            .iter()
            .zip(row)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "image {} batch-vs-single err {}", i, max_err);
    }
}

#[test]
fn coordinator_serves_concurrent_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(4) };
    let coord = Arc::new(
        Coordinator::start_pjrt(&dir, "test-tiny_b8_rb0.7_rt0.7_bs4", policy).expect("start"),
    );
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            for i in 0..8u64 {
                let mut rng = vitfpga::util::rng::Rng::new(c * 100 + i);
                let img: Vec<f32> = (0..coord.input_elems_per_image)
                    .map(|_| rng.normal())
                    .collect();
                let resp = coord.infer(img).expect("infer");
                assert_eq!(resp.logits.len(), coord.num_classes);
                assert!(resp.predicted_class < coord.num_classes);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics().expect("metrics");
    assert_eq!(m.requests, 32);
    assert!(m.batches <= 32);
    assert!(m.mean_batch_occupancy >= 1.0);
}

#[test]
fn coordinator_batches_under_load() {
    let Some(dir) = artifacts_dir() else { return };
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(20) };
    let coord = Arc::new(
        Coordinator::start_pjrt(&dir, "test-tiny_b8_rb0.7_rt0.7_bs4", policy).expect("start"),
    );
    // Fire 16 requests at once; with a 20 ms window the batcher should
    // pack them into fewer than 16 executions.
    let mut rxs = Vec::new();
    for i in 0..16u64 {
        let mut rng = vitfpga::util::rng::Rng::new(i);
        let img: Vec<f32> = (0..coord.input_elems_per_image)
            .map(|_| rng.normal())
            .collect();
        rxs.push(coord.submit(img).unwrap());
    }
    for rx in rxs {
        rx.recv().unwrap().expect("response");
    }
    let m = coord.metrics().unwrap();
    assert_eq!(m.requests, 16);
    assert!(m.batches < 16, "no batching happened: {} batches", m.batches);
    assert!(m.mean_batch_occupancy > 1.0);
}

#[test]
fn coordinator_rejects_wrong_image_size() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start_pjrt(
        &dir,
        "test-tiny_b8_rb0.7_rt0.7_bs1",
        BatchPolicy::default(),
    )
    .expect("start");
    assert!(coord.submit(vec![0.0; 3]).is_err());
}

// NOTE: the simulator-vs-structure-file tests live in
// rust/tests/structure.rs — they need artifacts but not PJRT, so they
// run on default features.
