//! Integration battery for the model registry: lazy per-model pool
//! construction (once, even under racing first requests), routing with
//! typed `UnknownModel` errors, per-model admission isolation (one
//! overloaded variant never sheds another), bit-exact parity between a
//! registry-served model and a dedicated pool built from the same
//! spec, and the shared CLI construction path (`registry::from_cli`)
//! in both legacy and registry modes. Default feature set only.

use std::sync::Arc;
use std::time::Duration;

use vitfpga::backend::NativeBackend;
use vitfpga::coordinator::{BackendPool, BatchPolicy, Overloaded, PoolPolicy};
use vitfpga::registry::{self, ModelSpec, Registry, UnknownModel};
use vitfpga::util::cli::Args;
use vitfpga::util::rng::Rng;

const FAST_SPEC: &str = "test-tiny@b8_rb0.5_rt0.5@seed=5";
const ACCURATE_SPEC: &str = "test-tiny@b8_rb0.7_rt0.9@seed=6";

fn batch_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }
}

fn registry() -> Registry {
    let defaults = PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 64 };
    Registry::builder(defaults)
        .register("fast", ModelSpec::parse(FAST_SPEC).unwrap(), Some(1))
        .unwrap()
        .register("accurate", ModelSpec::parse(ACCURATE_SPEC).unwrap(), Some(1))
        .unwrap()
        .finish()
        .unwrap()
}

fn images(n: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..per).map(|_| rng.normal()).collect())
        .collect()
}

#[test]
fn racing_first_requests_build_one_pool() {
    // 8 threads all fire the first request for the same cold model; the
    // entry mutex must build exactly one pool, and every request must
    // answer through it.
    let reg = Arc::new(registry());
    assert!(!reg.is_ready("fast"), "registration must not construct");
    let per = reg.describe("fast").unwrap().input_elems_per_image;
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                let img = images(1, per, t).remove(0);
                reg.infer(Some("fast"), img).expect("racing first infer")
            })
        })
        .collect();
    for h in handles {
        let resp = h.join().expect("client thread");
        assert_eq!(resp.model.as_str(), "fast", "responses carry the model id");
    }
    assert!(reg.is_ready("fast"));
    assert!(!reg.is_ready("accurate"), "untouched model stays cold");
    let pool = reg.ready_pool("fast").expect("built pool");
    assert_eq!(
        pool.metrics().expect("pool metrics").pool.requests,
        8,
        "one pool answered all racing requests"
    );
    // The second lookup must hand back the same pool, not rebuild.
    assert!(Arc::ptr_eq(&pool, &reg.pool("fast").expect("pool")));
}

#[test]
fn registry_parity_with_dedicated_pool_per_variant() {
    // Acceptance bar (in-process half): for each registered variant,
    // routing through the registry is bit-exact against a dedicated
    // single-model pool built from the same spec.
    let reg = registry();
    for spec_str in [FAST_SPEC, ACCURATE_SPEC] {
        let name = if spec_str == FAST_SPEC { "fast" } else { "accurate" };
        let spec = ModelSpec::parse(spec_str).unwrap();
        let dedicated = BackendPool::start(
            move |_i| NativeBackend::from_spec(&spec).map(|nb| nb.with_threads(1)),
            PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 64 },
        )
        .expect("dedicated pool");
        for img in images(4, dedicated.input_elems_per_image, 31) {
            let got = reg.infer(Some(name), img.clone()).expect("registry infer");
            let want = dedicated.infer(img).expect("dedicated infer");
            assert_eq!(got.logits, want.logits, "{} logits diverge", name);
            assert_eq!(got.predicted_class, want.predicted_class);
        }
    }
}

#[test]
fn unknown_model_is_typed_and_infer_deadline_routes() {
    let reg = registry();
    let per = reg.describe("fast").unwrap().input_elems_per_image;
    let err = reg
        .infer(Some("nope"), vec![0.0; per])
        .expect_err("unknown model must fail");
    let u = err.downcast_ref::<UnknownModel>().expect("typed UnknownModel");
    assert_eq!(u.requested, "nope");
    assert_eq!(u.known, vec!["fast".to_string(), "accurate".to_string()]);
    assert!(!reg.is_ready("fast"), "a failed resolve must not build anything");

    // None routes to the default (first-registered) model, with the
    // pool's deadline semantics intact.
    let resp = reg
        .infer_deadline(None, images(1, per, 3).remove(0), Some(Duration::from_secs(30)))
        .expect("default-model infer");
    assert_eq!(resp.model.as_str(), "fast");
}

/// Deterministic slow stand-in backend (logits[j] = image[0] + j) to
/// hold a request in flight for a known window.
struct SlowBackend {
    delay: Duration,
}

impl vitfpga::backend::Backend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }
    fn batch_capacity(&self) -> usize {
        4
    }
    fn num_classes(&self) -> usize {
        4
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(
        &mut self,
        flat: &[f32],
        batch: usize,
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        for i in 0..batch {
            for j in 0..4 {
                out[i * 4 + j] = flat[i * 2] + j as f32;
            }
        }
        Ok(())
    }
}

#[test]
fn per_model_queue_capacity_isolates_admission() {
    // "tight" is a capacity-1 pool over a deliberately slow backend;
    // "roomy" is a spec variant with the 64-slot default. Saturating
    // "tight" must shed it — and only it.
    let defaults = PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 64 };
    let tight_raw = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(200) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 1 },
    )
    .expect("tight pool start");
    let reg = Arc::new(
        Registry::builder(defaults)
            .register_pool("tight", tight_raw)
            .unwrap()
            .register("roomy", ModelSpec::parse(ACCURATE_SPEC).unwrap(), Some(1))
            .unwrap()
            .finish()
            .unwrap(),
    );
    let tight = reg.describe("tight").unwrap();
    assert_eq!(tight.queue_capacity, 1, "per-model queue capacity is honoured");
    assert_eq!(reg.describe("roomy").unwrap().queue_capacity, 64);

    // Occupy tight's only admission slot for >= 200 ms...
    let tight_pool = reg.pool("tight").expect("tight pool");
    let held = tight_pool.submit(vec![1.0, 0.0]).expect("first submit fills the slot");
    let shed = tight_pool
        .submit(vec![2.0, 0.0])
        .expect_err("second submit over capacity 1");
    assert!(shed.downcast_ref::<Overloaded>().is_some(), "typed shed: {:#}", shed);
    // ...while the other model is untouched by tight's backpressure.
    let roomy_per = reg.describe("roomy").unwrap().input_elems_per_image;
    reg.infer(Some("roomy"), images(1, roomy_per, 9).remove(0))
        .expect("roomy model serves while tight sheds");
    assert_eq!(reg.ready_pool("roomy").unwrap().stats().shed_count, 0);
    held.recv()
        .expect("engine answers the held request")
        .expect("held request infers");
}

#[test]
fn from_cli_registry_mode_round_trips_specs() {
    let argv = [
        "serve",
        "--replicas", "1",
        "--queue-capacity", "32",
        "--max-batch", "4",
        "--threads", "1",
        "--model", "fast=test-tiny@b8_rb0.5_rt0.5@seed=5",
        "--model", "accurate=test-tiny@b8_rb0.7_rt0.9@seed=6@queue=16",
        "--default-model", "accurate",
    ];
    let args = Args::parse(argv.iter().map(|s| s.to_string()));
    let reg = registry::from_cli(&args, registry::pool_policy_from_cli(&args))
        .expect("registry mode from cli");
    assert_eq!(reg.names(), ["fast".to_string(), "accurate".to_string()]);
    assert_eq!(reg.default_model(), "accurate", "--default-model wins over first");
    assert_eq!(reg.spec_of("fast").unwrap().spec_string(), FAST_SPEC);
    let accurate = reg.describe("accurate").unwrap();
    assert_eq!(accurate.queue_capacity, 16, "spec override");
    assert_eq!(reg.describe("fast").unwrap().queue_capacity, 32, "cli default");
    // End to end through the CLI-built registry.
    let per = accurate.input_elems_per_image;
    let resp = reg.infer(None, images(1, per, 13).remove(0)).expect("default infer");
    assert_eq!(resp.model.as_str(), "accurate");
}

#[test]
fn from_cli_legacy_mode_registers_default_pool() {
    // No NAME=SPEC values: the legacy flag set builds one prebuilt pool
    // under the "default" name — the pre-registry CLI contract.
    let argv = [
        "serve",
        "--model", "test-tiny",
        "--setting", "b8_rb0.7_rt0.7",
        "--threads", "1",
        "--max-batch", "4",
    ];
    let args = Args::parse(argv.iter().map(|s| s.to_string()));
    let reg = registry::from_cli(&args, registry::pool_policy_from_cli(&args))
        .expect("legacy mode from cli");
    assert_eq!(reg.names(), [registry::DEFAULT_MODEL.to_string()]);
    assert!(reg.is_ready(registry::DEFAULT_MODEL), "legacy pools are prebuilt");
    let info = reg.describe(registry::DEFAULT_MODEL).unwrap();
    assert!(info.spec.is_none(), "prebuilt entries carry no spec");
    assert_eq!(info.input_elems_per_image, 32 * 32 * 3);
    let resp = reg
        .infer(None, images(1, info.input_elems_per_image, 17).remove(0))
        .expect("legacy default infer");
    assert_eq!(resp.model.as_str(), registry::DEFAULT_MODEL);
    assert_eq!(resp.logits.len(), info.num_classes);
}
