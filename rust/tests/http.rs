//! Integration battery for the HTTP serving edge, driven over real
//! loopback sockets: response parity with direct `BackendPool::infer`,
//! typed-error -> status-code mapping (429 shed with `Retry-After`,
//! 504 deadline), malformed/oversized body rejection, Prometheus
//! scrape well-formedness with advancing counters, keep-alive reuse,
//! and graceful drain-on-shutdown. Runs with the default feature set —
//! no artifacts, no XLA toolchain, no non-std dependencies.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use vitfpga::backend::{Backend, NativeBackend};
use vitfpga::config::{PruningSetting, TEST_TINY};
use vitfpga::coordinator::{BackendPool, BatchPolicy, PoolPolicy};
use vitfpga::funcsim::Precision;
use vitfpga::server::{route, AppState, HttpClient, HttpConfig, HttpRequest, HttpServer};
use vitfpga::util::json::Json;
use vitfpga::util::rng::Rng;

const SEED: u64 = 42;

/// Deterministic instant backend: logits[j] = image[0] + j.
struct EchoBackend;

impl Backend for EchoBackend {
    fn name(&self) -> &str {
        "echo"
    }
    fn batch_capacity(&self) -> usize {
        8
    }
    fn num_classes(&self) -> usize {
        4
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        for i in 0..batch {
            for j in 0..4 {
                out[i * 4 + j] = flat[i * 2] + j as f32;
            }
        }
        Ok(())
    }
}

/// Echo with a per-batch delay — widens in-flight windows so shed,
/// deadline and drain behaviour are deterministic.
struct SlowBackend {
    delay: Duration,
}

impl Backend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }
    fn batch_capacity(&self) -> usize {
        8
    }
    fn num_classes(&self) -> usize {
        4
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        std::thread::sleep(self.delay);
        for i in 0..batch {
            for j in 0..4 {
                out[i * 4 + j] = flat[i * 2] + j as f32;
            }
        }
        Ok(())
    }
}

fn batch_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }
}

fn native_pool(replicas: usize) -> BackendPool {
    BackendPool::start(
        |_i| NativeBackend::synthetic(&TEST_TINY, &PruningSetting::new(8, 0.7, 0.7), SEED, Precision::F32),
        PoolPolicy { replicas, batch: batch_policy(), queue_capacity: 64 },
    )
    .expect("native pool start")
}

/// Boot a server on an ephemeral loopback port over `pool`.
fn serve(
    pool: BackendPool,
    timeout: Option<Duration>,
    config: HttpConfig,
) -> (HttpServer, Arc<AppState>) {
    let state = Arc::new(AppState::new(pool, timeout));
    let handler_state = Arc::clone(&state);
    let server = HttpServer::start("127.0.0.1:0", config, move |req: &HttpRequest| {
        route(&handler_state, req)
    })
    .expect("http server start");
    (server, state)
}

fn client_for(server: &HttpServer) -> HttpClient {
    HttpClient::connect(&server.local_addr().to_string(), Duration::from_secs(10))
        .expect("client connect")
}

fn image_body(img: &[f32]) -> Vec<u8> {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "image".to_string(),
        Json::Arr(img.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    Json::Obj(m).to_string().into_bytes()
}

fn images_body(imgs: &[Vec<f32>]) -> Vec<u8> {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "images".to_string(),
        Json::Arr(
            imgs.iter()
                .map(|img| Json::Arr(img.iter().map(|&v| Json::Num(v as f64)).collect()))
                .collect(),
        ),
    );
    Json::Obj(m).to_string().into_bytes()
}

fn logits_of(j: &Json) -> Vec<f32> {
    j.get("logits")
        .and_then(|l| l.as_arr())
        .expect("response carries logits")
        .iter()
        .map(|v| v.as_f64().expect("logit is a number") as f32)
        .collect()
}

fn synthetic_images(n: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..per).map(|_| rng.normal()).collect())
        .collect()
}

// ---------------------------------------------------------------------------

#[test]
fn infer_parity_with_direct_pool() {
    // The same pool answers over HTTP and in-process; logits must match
    // bit-for-bit (f32 -> JSON f64 shortest-repr -> f32 is lossless).
    let (server, state) = serve(native_pool(1), None, HttpConfig::default());
    let per = state.pool.input_elems_per_image;
    let mut client = client_for(&server);
    for (i, img) in synthetic_images(3, per, 7).into_iter().enumerate() {
        let resp = client
            .post("/v1/infer", &image_body(&img))
            .expect("http infer");
        assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
        let j = resp.json().expect("response is JSON");
        let want = state.pool.infer(img).expect("direct pool infer");
        assert_eq!(logits_of(&j), want.logits, "image {}: HTTP logits != pool logits", i);
        assert_eq!(
            j.get("predicted_class").and_then(|v| v.as_usize()),
            Some(want.predicted_class),
            "image {}: argmax mismatch",
            i
        );
        // Queue/latency metadata is present and sane.
        assert!(j.get("latency_ms").and_then(|v| v.as_f64()).unwrap_or(-1.0) >= 0.0);
        assert!(j.get("batch_size").and_then(|v| v.as_usize()).unwrap_or(0) >= 1);
        assert!(j.get("queue_depth").and_then(|v| v.as_f64()).is_some());
    }
}

#[test]
fn batch_parity_with_direct_pool() {
    let (server, state) = serve(native_pool(2), None, HttpConfig::default());
    let per = state.pool.input_elems_per_image;
    let imgs = synthetic_images(3, per, 11);
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer_batch", &images_body(&imgs))
        .expect("http infer_batch");
    assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
    let j = resp.json().expect("response is JSON");
    assert_eq!(j.get("count").and_then(|v| v.as_usize()), Some(3));
    let results = j.get("results").and_then(|r| r.as_arr()).expect("results array");
    assert_eq!(results.len(), 3);
    for (i, (r, img)) in results.iter().zip(&imgs).enumerate() {
        let want = state.pool.infer(img.clone()).expect("direct pool infer");
        assert_eq!(logits_of(r), want.logits, "batch item {} logits mismatch", i);
    }
}

#[test]
fn shed_maps_to_429_with_retry_after() {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(200) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 2 },
    )
    .expect("slow pool start");
    let (server, state) = serve(pool, None, HttpConfig::default());
    // Fill both admission slots directly at the pool...
    let a = state.pool.submit(vec![1.0, 0.0]).expect("slot 1");
    let b = state.pool.submit(vec![2.0, 0.0]).expect("slot 2");
    // ...then the HTTP request must shed.
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer", &image_body(&[3.0, 0.0]))
        .expect("http exchange");
    assert_eq!(resp.status, 429);
    assert_eq!(resp.header("retry-after"), Some("1"), "429 must carry Retry-After");
    let j = resp.json().expect("shed body is JSON");
    assert_eq!(j.get("queue_capacity").and_then(|v| v.as_usize()), Some(2));
    drop(a);
    drop(b);
}

#[test]
fn request_deadline_maps_to_504() {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(500) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("slow pool start");
    let (server, _state) = serve(pool, Some(Duration::from_millis(30)), HttpConfig::default());
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer", &image_body(&[1.0, 0.0]))
        .expect("http exchange");
    assert_eq!(resp.status, 504, "30 ms deadline against a 500 ms backend");
    let batch = client
        .post("/v1/infer_batch", &images_body(&[vec![1.0, 0.0], vec![2.0, 0.0]]))
        .expect("http exchange");
    assert_eq!(batch.status, 504, "batch route honours the deadline too");
}

#[test]
fn malformed_bodies_map_to_400() {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let (server, _state) = serve(pool, None, HttpConfig::default());
    let mut client = client_for(&server);
    for (what, body) in [
        ("unparseable JSON", b"{not json".to_vec()),
        ("missing image field", b"{\"img\":[1,2]}".to_vec()),
        ("non-array image", b"{\"image\":3}".to_vec()),
        ("non-numeric entries", b"{\"image\":[1,\"x\"]}".to_vec()),
        ("wrong length", image_body(&[1.0, 2.0, 3.0])),
        ("empty batch", b"{\"images\":[]}".to_vec()),
    ] {
        let resp = client.post("/v1/infer", &body).expect("http exchange");
        // The batch-shaped probe goes to the batch route.
        let status = if what == "empty batch" {
            client
                .post("/v1/infer_batch", &body)
                .expect("http exchange")
                .status
        } else {
            resp.status
        };
        assert_eq!(status, 400, "{} must map to 400", what);
    }
    // Routing errors.
    assert_eq!(client.get("/nope").expect("404 route").status, 404);
    assert_eq!(client.get("/v1/infer").expect("405 route").status, 405);
}

#[test]
fn oversized_body_maps_to_413() {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let config = HttpConfig { max_body_bytes: 128, ..HttpConfig::default() };
    let (server, _state) = serve(pool, None, config);
    let mut client = client_for(&server);
    let big = image_body(&[0.123456f32; 200]);
    assert!(big.len() > 128);
    let resp = client.post("/v1/infer", &big).expect("http exchange");
    assert_eq!(resp.status, 413, "body over max_body_bytes is rejected before buffering");
    // The connection was closed by the reject; the client transparently
    // reconnects and the edge still serves.
    let ok = client.post("/v1/infer", &image_body(&[1.0, 2.0])).expect("follow-up");
    assert_eq!(ok.status, 200);
}

#[test]
fn chunked_transfer_encoding_maps_to_411() {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let (server, _state) = serve(pool, None, HttpConfig::default());
    // Raw socket: the HttpClient never sends chunked bodies.
    let mut stream = TcpStream::connect(server.local_addr()).expect("raw connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .expect("raw read timeout");
    stream
        .write_all(
            b"POST /v1/infer HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: chunked\r\n\r\n",
        )
        .expect("raw write");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("raw read");
    assert!(
        response.starts_with("HTTP/1.1 411 "),
        "chunked must be rejected with 411, got: {}",
        response.lines().next().unwrap_or("")
    );
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    let (server, state) = serve(native_pool(1), None, HttpConfig::default());
    let per = state.pool.input_elems_per_image;
    let mut client = client_for(&server);
    let img = synthetic_images(1, per, 3).remove(0);
    for round in 0..3 {
        let health = client.get("/healthz").expect("healthz");
        assert_eq!(health.status, 200, "round {}", round);
        let resp = client.post("/v1/infer", &image_body(&img)).expect("infer");
        assert_eq!(resp.status, 200, "round {}", round);
    }
    // healthz reports the model shape loadgen needs.
    let j = client.get("/healthz").expect("healthz").json().expect("json");
    assert_eq!(j.get("input_elems_per_image").and_then(|v| v.as_usize()), Some(per));
    assert_eq!(j.get("status").and_then(|v| v.as_str()), Some("ok"));
}

/// Pull one labelled-or-not sample value out of a Prometheus exposition.
fn prom_value(text: &str, name_with_labels: &str) -> Option<f64> {
    text.lines()
        .find(|l| l.starts_with(name_with_labels) && !l.starts_with('#'))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

#[test]
fn metrics_scrape_parses_and_counters_advance() {
    let (server, state) = serve(native_pool(2), None, HttpConfig::default());
    let per = state.pool.input_elems_per_image;
    let mut client = client_for(&server);

    let scrape = |client: &mut HttpClient| -> String {
        let resp = client.get("/metrics").expect("metrics scrape");
        assert_eq!(resp.status, 200);
        assert!(
            resp.header("content-type").unwrap_or("").starts_with("text/plain"),
            "Prometheus exposition is text/plain"
        );
        String::from_utf8(resp.body.clone()).expect("exposition is UTF-8")
    };

    let before = scrape(&mut client);
    // Every sample line is `name[{labels}] value` with a finite value.
    let mut samples = 0;
    for line in before.lines().filter(|l| !l.starts_with('#') && !l.trim().is_empty()) {
        let (name, value) = line.rsplit_once(' ').expect("sample line has a value");
        assert!(!name.is_empty());
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("unparseable value: {}", line));
        assert!(v.is_finite(), "non-finite sample: {}", line);
        samples += 1;
    }
    assert!(samples >= 10, "exposition should carry the full gauge set, got {}", samples);

    let infer_before =
        prom_value(&before, "vitfpga_http_route_requests_total{route=\"infer\"}").unwrap_or(0.0);
    let pool_before = prom_value(&before, "vitfpga_pool_requests_total").unwrap_or(0.0);

    let img = synthetic_images(1, per, 5).remove(0);
    for _ in 0..3 {
        assert_eq!(client.post("/v1/infer", &image_body(&img)).expect("infer").status, 200);
    }

    let after = scrape(&mut client);
    let infer_after =
        prom_value(&after, "vitfpga_http_route_requests_total{route=\"infer\"}").expect("counter");
    let pool_after = prom_value(&after, "vitfpga_pool_requests_total").expect("counter");
    assert_eq!(infer_after, infer_before + 3.0, "HTTP route counter must advance");
    assert_eq!(pool_after, pool_before + 3.0, "pool request counter must advance");
    assert!(
        prom_value(&after, "vitfpga_pool_latency_ms_count").unwrap_or(0.0) >= 3.0,
        "latency summary count tracks answered requests"
    );
}

#[test]
fn graceful_shutdown_drains_in_flight_before_socket_closes() {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(300) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("slow pool start");
    let (mut server, _state) = serve(pool, None, HttpConfig::default());
    let addr = server.local_addr();

    // A request that will still be executing when shutdown starts.
    let worker = std::thread::spawn(move || {
        let mut client =
            HttpClient::connect(&addr.to_string(), Duration::from_secs(10)).expect("client");
        client.post("/v1/infer", &image_body(&[5.0, 0.0]))
    });
    // Wait until the server has parsed it (it is now in flight).
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.in_flight() == 0 {
        assert!(Instant::now() < deadline, "request never became in-flight");
        std::thread::sleep(Duration::from_millis(2));
    }

    server.shutdown();

    // The in-flight request was answered, not reset.
    let resp = worker.join().expect("client thread").expect("drained response");
    assert_eq!(resp.status, 200, "in-flight request must complete through the drain");
    let j = resp.json().expect("drained body is JSON");
    assert_eq!(logits_of(&j), vec![5.0, 6.0, 7.0, 8.0]);

    // And only after the drain did the socket close.
    let refused = TcpStream::connect_timeout(&addr, Duration::from_millis(500));
    assert!(refused.is_err(), "listener must be closed after shutdown");
}

#[test]
fn concurrent_keep_alive_clients_all_answered() {
    // The acceptance-bar smoke: N concurrent keep-alive clients, each
    // issuing several requests, all answered correctly by the pool.
    let (server, state) = serve(native_pool(2), None, HttpConfig::default());
    let per = state.pool.input_elems_per_image;
    let addr = server.local_addr().to_string();
    let want = state
        .pool
        .infer(synthetic_images(1, per, 21).remove(0))
        .expect("reference infer")
        .logits;

    let handles: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.clone();
            let want = want.clone();
            std::thread::spawn(move || {
                let mut client =
                    HttpClient::connect(&addr, Duration::from_secs(10)).expect("client");
                // Learn the model shape the way loadgen does.
                let health = client.get("/healthz").expect("healthz").json().expect("json");
                let per = health
                    .get("input_elems_per_image")
                    .and_then(|v| v.as_usize())
                    .expect("shape");
                let img = synthetic_images(1, per, 21).remove(0);
                for _ in 0..4 {
                    let resp = client.post("/v1/infer", &image_body(&img)).expect("infer");
                    assert_eq!(resp.status, 200);
                    assert_eq!(logits_of(&resp.json().expect("json")), want);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let m = state.pool.metrics().expect("pool metrics");
    assert!(m.pool.requests >= 24, "all 6x4 HTTP requests reached the pool");
}
