//! Integration battery for the HTTP serving edge, driven over real
//! loopback sockets: response parity with direct `BackendPool::infer`,
//! typed-error -> status-code mapping (429 shed with a computed
//! `Retry-After`, 504 deadline, 404 unknown model), mixed-model
//! routing through the registry (per-model parity with dedicated
//! pools, `model="..."` metric labels, `--model-mix` loadgen),
//! malformed/oversized body rejection, Prometheus scrape
//! well-formedness with advancing counters, keep-alive reuse,
//! pipelining, the connection cap, and graceful drain-on-shutdown.
//! The transport battery runs against *both* edges — the
//! thread-per-connection baseline and the nonblocking readiness loop
//! (`*_evented` tests) — which must behave bit-identically on the
//! wire. The binary tensor wire format (raw little-endian f32 bodies)
//! is covered for exact round-trip parity with JSON, framing errors,
//! `Accept` negotiation, and mixed-encoding keep-alive connections.
//! Runs with the default feature set — no artifacts, no XLA
//! toolchain, no non-std dependencies.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use vitfpga::backend::{Backend, NativeBackend};
use vitfpga::config::{PruningSetting, TEST_TINY};
use vitfpga::coordinator::{BackendPool, BatchPolicy, PoolPolicy};
use vitfpga::funcsim::Precision;
use vitfpga::registry::{ModelSpec, Registry};
use vitfpga::server::{
    route, AppState, EdgeKind, HttpClient, HttpConfig, HttpRequest, HttpServer,
    BINARY_CONTENT_TYPE,
};
use vitfpga::util::json::Json;
use vitfpga::util::rng::Rng;

const SEED: u64 = 42;

/// Deterministic instant backend: logits[j] = image[0] + j.
struct EchoBackend;

impl Backend for EchoBackend {
    fn name(&self) -> &str {
        "echo"
    }
    fn batch_capacity(&self) -> usize {
        8
    }
    fn num_classes(&self) -> usize {
        4
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        for i in 0..batch {
            for j in 0..4 {
                out[i * 4 + j] = flat[i * 2] + j as f32;
            }
        }
        Ok(())
    }
}

/// Echo with a per-batch delay — widens in-flight windows so shed,
/// deadline and drain behaviour are deterministic.
struct SlowBackend {
    delay: Duration,
}

impl Backend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }
    fn batch_capacity(&self) -> usize {
        8
    }
    fn num_classes(&self) -> usize {
        4
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        std::thread::sleep(self.delay);
        for i in 0..batch {
            for j in 0..4 {
                out[i * 4 + j] = flat[i * 2] + j as f32;
            }
        }
        Ok(())
    }
}

fn batch_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }
}

fn native_pool(replicas: usize) -> BackendPool {
    BackendPool::start(
        |_i| NativeBackend::synthetic(&TEST_TINY, &PruningSetting::new(8, 0.7, 0.7), SEED, Precision::F32),
        PoolPolicy { replicas, batch: batch_policy(), queue_capacity: 64 },
    )
    .expect("native pool start")
}

/// Boot a server on an ephemeral loopback port over `pool`
/// (thread-per-connection edge).
fn serve(
    pool: BackendPool,
    timeout: Option<Duration>,
    config: HttpConfig,
) -> (HttpServer, Arc<AppState>) {
    serve_on(EdgeKind::Threaded, pool, timeout, config)
}

/// Boot a server on an ephemeral loopback port over `pool` on the
/// given transport edge.
fn serve_on(
    edge: EdgeKind,
    pool: BackendPool,
    timeout: Option<Duration>,
    config: HttpConfig,
) -> (HttpServer, Arc<AppState>) {
    serve_registry_on(edge, Registry::single(pool), timeout, config)
}

/// Boot a server over a full model registry (threaded edge).
fn serve_registry(
    registry: Registry,
    timeout: Option<Duration>,
    config: HttpConfig,
) -> (HttpServer, Arc<AppState>) {
    serve_registry_on(EdgeKind::Threaded, registry, timeout, config)
}

/// Boot a server over a full model registry on the given edge, with
/// the state's transport stats wired in (so `/metrics` sees the
/// connection gauge and overflow counter).
fn serve_registry_on(
    edge: EdgeKind,
    registry: Registry,
    timeout: Option<Duration>,
    config: HttpConfig,
) -> (HttpServer, Arc<AppState>) {
    let state = Arc::new(AppState::with_registry(registry, timeout));
    let handler_state = Arc::clone(&state);
    let server = HttpServer::start_with(
        "127.0.0.1:0",
        config,
        edge,
        Arc::clone(&state.transport),
        move |req: &HttpRequest| route(&handler_state, req),
    )
    .expect("http server start");
    (server, state)
}

/// The state's default-model pool (always prebuilt in these tests).
fn pool_of(state: &AppState) -> Arc<BackendPool> {
    state.default_pool().expect("default pool")
}

fn client_for(server: &HttpServer) -> HttpClient {
    HttpClient::connect(&server.local_addr().to_string(), Duration::from_secs(10))
        .expect("client connect")
}

fn image_body(img: &[f32]) -> Vec<u8> {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "image".to_string(),
        Json::Arr(img.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    Json::Obj(m).to_string().into_bytes()
}

fn images_body(imgs: &[Vec<f32>]) -> Vec<u8> {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "images".to_string(),
        Json::Arr(
            imgs.iter()
                .map(|img| Json::Arr(img.iter().map(|&v| Json::Num(v as f64)).collect()))
                .collect(),
        ),
    );
    Json::Obj(m).to_string().into_bytes()
}

fn logits_of(j: &Json) -> Vec<f32> {
    j.get("logits")
        .and_then(|l| l.as_arr())
        .expect("response carries logits")
        .iter()
        .map(|v| v.as_f64().expect("logit is a number") as f32)
        .collect()
}

fn synthetic_images(n: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..per).map(|_| rng.normal()).collect())
        .collect()
}

// ---------------------------------------------------------------------------

#[test]
fn infer_parity_with_direct_pool() {
    infer_parity_on(EdgeKind::Threaded);
}

#[test]
fn infer_parity_evented() {
    infer_parity_on(EdgeKind::Evented);
}

fn infer_parity_on(edge: EdgeKind) {
    // The same pool answers over HTTP and in-process; logits must match
    // bit-for-bit (f32 -> JSON f64 shortest-repr -> f32 is lossless).
    let (server, state) = serve_on(edge, native_pool(1), None, HttpConfig::default());
    let pool = pool_of(&state);
    let per = pool.input_elems_per_image;
    let mut client = client_for(&server);
    for (i, img) in synthetic_images(3, per, 7).into_iter().enumerate() {
        let resp = client
            .post("/v1/infer", &image_body(&img))
            .expect("http infer");
        assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
        let j = resp.json().expect("response is JSON");
        let want = pool.infer(img).expect("direct pool infer");
        assert_eq!(logits_of(&j), want.logits, "image {}: HTTP logits != pool logits", i);
        assert_eq!(
            j.get("predicted_class").and_then(|v| v.as_usize()),
            Some(want.predicted_class),
            "image {}: argmax mismatch",
            i
        );
        // Queue/latency metadata is present and sane.
        assert!(j.get("latency_ms").and_then(|v| v.as_f64()).unwrap_or(-1.0) >= 0.0);
        assert!(j.get("batch_size").and_then(|v| v.as_usize()).unwrap_or(0) >= 1);
        assert!(j.get("queue_depth").and_then(|v| v.as_f64()).is_some());
    }
}

#[test]
fn batch_parity_with_direct_pool() {
    batch_parity_on(EdgeKind::Threaded);
}

#[test]
fn batch_parity_evented() {
    batch_parity_on(EdgeKind::Evented);
}

fn batch_parity_on(edge: EdgeKind) {
    let (server, state) = serve_on(edge, native_pool(2), None, HttpConfig::default());
    let pool = pool_of(&state);
    let per = pool.input_elems_per_image;
    let imgs = synthetic_images(3, per, 11);
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer_batch", &images_body(&imgs))
        .expect("http infer_batch");
    assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
    let j = resp.json().expect("response is JSON");
    assert_eq!(j.get("count").and_then(|v| v.as_usize()), Some(3));
    let results = j.get("results").and_then(|r| r.as_arr()).expect("results array");
    assert_eq!(results.len(), 3);
    for (i, (r, img)) in results.iter().zip(&imgs).enumerate() {
        let want = pool.infer(img.clone()).expect("direct pool infer");
        assert_eq!(logits_of(r), want.logits, "batch item {} logits mismatch", i);
    }
}

#[test]
fn shed_maps_to_429_with_retry_after() {
    shed_maps_to_429_on(EdgeKind::Threaded);
}

#[test]
fn shed_maps_to_429_evented() {
    shed_maps_to_429_on(EdgeKind::Evented);
}

fn shed_maps_to_429_on(edge: EdgeKind) {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(200) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 2 },
    )
    .expect("slow pool start");
    let (server, state) = serve_on(edge, pool, None, HttpConfig::default());
    let direct = pool_of(&state);
    // Fill both admission slots directly at the pool...
    let a = direct.submit(vec![1.0, 0.0]).expect("slot 1");
    let b = direct.submit(vec![2.0, 0.0]).expect("slot 2");
    // ...then the HTTP request must shed.
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer", &image_body(&[3.0, 0.0]))
        .expect("http exchange");
    assert_eq!(resp.status, 429);
    // Retry-After is computed from the shedding pool's queue depth,
    // replica count and observed latency — not a constant. It must be
    // a positive integer within the clamp, and the JSON body must echo
    // the same value.
    let retry: u64 = resp
        .header("retry-after")
        .expect("429 must carry Retry-After")
        .parse()
        .expect("Retry-After is an integer");
    assert!((1..=60).contains(&retry), "Retry-After {} outside [1, 60]", retry);
    let j = resp.json().expect("shed body is JSON");
    assert_eq!(j.get("queue_capacity").and_then(|v| v.as_usize()), Some(2));
    assert_eq!(
        j.get("retry_after_s").and_then(|v| v.as_usize()),
        Some(retry as usize),
        "body retry_after_s must match the header"
    );
    drop(a);
    drop(b);
}

#[test]
fn request_deadline_maps_to_504() {
    deadline_maps_to_504_on(EdgeKind::Threaded);
}

#[test]
fn request_deadline_evented() {
    deadline_maps_to_504_on(EdgeKind::Evented);
}

fn deadline_maps_to_504_on(edge: EdgeKind) {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(500) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("slow pool start");
    let (server, _state) =
        serve_on(edge, pool, Some(Duration::from_millis(30)), HttpConfig::default());
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer", &image_body(&[1.0, 0.0]))
        .expect("http exchange");
    assert_eq!(resp.status, 504, "30 ms deadline against a 500 ms backend");
    let batch = client
        .post("/v1/infer_batch", &images_body(&[vec![1.0, 0.0], vec![2.0, 0.0]]))
        .expect("http exchange");
    assert_eq!(batch.status, 504, "batch route honours the deadline too");
}

#[test]
fn malformed_bodies_map_to_400() {
    malformed_bodies_on(EdgeKind::Threaded);
}

#[test]
fn malformed_bodies_evented() {
    malformed_bodies_on(EdgeKind::Evented);
}

fn malformed_bodies_on(edge: EdgeKind) {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let (server, _state) = serve_on(edge, pool, None, HttpConfig::default());
    let mut client = client_for(&server);
    for (what, body) in [
        ("unparseable JSON", b"{not json".to_vec()),
        ("missing image field", b"{\"img\":[1,2]}".to_vec()),
        ("non-array image", b"{\"image\":3}".to_vec()),
        ("non-numeric entries", b"{\"image\":[1,\"x\"]}".to_vec()),
        ("wrong length", image_body(&[1.0, 2.0, 3.0])),
        ("empty batch", b"{\"images\":[]}".to_vec()),
    ] {
        let resp = client.post("/v1/infer", &body).expect("http exchange");
        // The batch-shaped probe goes to the batch route.
        let status = if what == "empty batch" {
            client
                .post("/v1/infer_batch", &body)
                .expect("http exchange")
                .status
        } else {
            resp.status
        };
        assert_eq!(status, 400, "{} must map to 400", what);
    }
    // Routing errors.
    assert_eq!(client.get("/nope").expect("404 route").status, 404);
    assert_eq!(client.get("/v1/infer").expect("405 route").status, 405);
}

#[test]
fn oversized_body_maps_to_413() {
    oversized_body_on(EdgeKind::Threaded);
}

#[test]
fn oversized_body_evented() {
    oversized_body_on(EdgeKind::Evented);
}

fn oversized_body_on(edge: EdgeKind) {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let config = HttpConfig { max_body_bytes: 128, ..HttpConfig::default() };
    let (server, _state) = serve_on(edge, pool, None, config);
    let mut client = client_for(&server);
    let big = image_body(&[0.123456f32; 200]);
    assert!(big.len() > 128);
    let resp = client.post("/v1/infer", &big).expect("http exchange");
    assert_eq!(resp.status, 413, "body over max_body_bytes is rejected before buffering");
    // The connection was closed by the reject; the client transparently
    // reconnects and the edge still serves.
    let ok = client.post("/v1/infer", &image_body(&[1.0, 2.0])).expect("follow-up");
    assert_eq!(ok.status, 200);
}

#[test]
fn chunked_transfer_encoding_maps_to_411() {
    chunked_maps_to_411_on(EdgeKind::Threaded);
}

#[test]
fn chunked_transfer_encoding_evented() {
    chunked_maps_to_411_on(EdgeKind::Evented);
}

fn chunked_maps_to_411_on(edge: EdgeKind) {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let (server, _state) = serve_on(edge, pool, None, HttpConfig::default());
    // Raw socket: the HttpClient never sends chunked bodies.
    let mut stream = TcpStream::connect(server.local_addr()).expect("raw connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .expect("raw read timeout");
    stream
        .write_all(
            b"POST /v1/infer HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: chunked\r\n\r\n",
        )
        .expect("raw write");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("raw read");
    assert!(
        response.starts_with("HTTP/1.1 411 "),
        "chunked must be rejected with 411, got: {}",
        response.lines().next().unwrap_or("")
    );
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    keep_alive_sequential_on(EdgeKind::Threaded);
}

#[test]
fn keep_alive_sequential_evented() {
    keep_alive_sequential_on(EdgeKind::Evented);
}

fn keep_alive_sequential_on(edge: EdgeKind) {
    let (server, state) = serve_on(edge, native_pool(1), None, HttpConfig::default());
    let per = pool_of(&state).input_elems_per_image;
    let mut client = client_for(&server);
    let img = synthetic_images(1, per, 3).remove(0);
    for round in 0..3 {
        let health = client.get("/healthz").expect("healthz");
        assert_eq!(health.status, 200, "round {}", round);
        let resp = client.post("/v1/infer", &image_body(&img)).expect("infer");
        assert_eq!(resp.status, 200, "round {}", round);
    }
    // healthz reports the model shape loadgen needs.
    let j = client.get("/healthz").expect("healthz").json().expect("json");
    assert_eq!(j.get("input_elems_per_image").and_then(|v| v.as_usize()), Some(per));
    assert_eq!(j.get("status").and_then(|v| v.as_str()), Some("ok"));
}

/// Pull one labelled-or-not sample value out of a Prometheus exposition.
fn prom_value(text: &str, name_with_labels: &str) -> Option<f64> {
    text.lines()
        .find(|l| l.starts_with(name_with_labels) && !l.starts_with('#'))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

#[test]
fn metrics_scrape_parses_and_counters_advance() {
    let (server, state) = serve(native_pool(2), None, HttpConfig::default());
    let per = pool_of(&state).input_elems_per_image;
    let mut client = client_for(&server);

    let scrape = |client: &mut HttpClient| -> String {
        let resp = client.get("/metrics").expect("metrics scrape");
        assert_eq!(resp.status, 200);
        assert!(
            resp.header("content-type").unwrap_or("").starts_with("text/plain"),
            "Prometheus exposition is text/plain"
        );
        String::from_utf8(resp.body.clone()).expect("exposition is UTF-8")
    };

    let before = scrape(&mut client);
    // Every sample line is `name[{labels}] value` with a finite value.
    let mut samples = 0;
    for line in before.lines().filter(|l| !l.starts_with('#') && !l.trim().is_empty()) {
        let (name, value) = line.rsplit_once(' ').expect("sample line has a value");
        assert!(!name.is_empty());
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("unparseable value: {}", line));
        assert!(v.is_finite(), "non-finite sample: {}", line);
        samples += 1;
    }
    assert!(samples >= 10, "exposition should carry the full gauge set, got {}", samples);

    let infer_before =
        prom_value(&before, "vitfpga_http_route_requests_total{route=\"infer\"}").unwrap_or(0.0);
    let pool_before = prom_value(&before, "vitfpga_pool_requests_total").unwrap_or(0.0);

    let img = synthetic_images(1, per, 5).remove(0);
    for _ in 0..3 {
        assert_eq!(client.post("/v1/infer", &image_body(&img)).expect("infer").status, 200);
    }

    let after = scrape(&mut client);
    let infer_after =
        prom_value(&after, "vitfpga_http_route_requests_total{route=\"infer\"}").expect("counter");
    let pool_after = prom_value(&after, "vitfpga_pool_requests_total").expect("counter");
    assert_eq!(infer_after, infer_before + 3.0, "HTTP route counter must advance");
    assert_eq!(pool_after, pool_before + 3.0, "pool request counter must advance");
    assert!(
        prom_value(&after, "vitfpga_pool_latency_ms_count").unwrap_or(0.0) >= 3.0,
        "latency summary count tracks answered requests"
    );
}

// ---------------------------------------------------------------------------
// model registry over HTTP
// ---------------------------------------------------------------------------

const FAST_SPEC: &str = "test-tiny@b8_rb0.5_rt0.5@seed=5";
const ACCURATE_SPEC: &str = "test-tiny@b8_rb0.7_rt0.9@seed=6";

/// Two differently-pruned synth variants in one registry: "fast"
/// (heavier pruning) and "accurate" (lighter). One intra-layer worker
/// keeps the battery lean; results are thread-count independent.
fn two_variant_registry() -> Registry {
    let defaults = PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 64 };
    Registry::builder(defaults)
        .register("fast", ModelSpec::parse(FAST_SPEC).expect("fast spec"), Some(1))
        .expect("register fast")
        .register("accurate", ModelSpec::parse(ACCURATE_SPEC).expect("accurate spec"), Some(1))
        .expect("register accurate")
        .finish()
        .expect("two-variant registry")
}

/// A dedicated single-model pool built from the same spec a registry
/// entry uses — the bit-exact parity reference.
fn dedicated_pool(spec: &str) -> BackendPool {
    let spec = ModelSpec::parse(spec).expect("parity spec");
    BackendPool::start(
        move |_i| NativeBackend::from_spec(&spec).map(|nb| nb.with_threads(1)),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 64 },
    )
    .expect("dedicated pool start")
}

fn image_body_for(model: &str, img: &[f32]) -> Vec<u8> {
    let mut m = std::collections::BTreeMap::new();
    m.insert("model".to_string(), Json::Str(model.to_string()));
    m.insert(
        "image".to_string(),
        Json::Arr(img.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    Json::Obj(m).to_string().into_bytes()
}

#[test]
fn mixed_models_route_by_name_with_parity_and_labels() {
    // The acceptance bar: one server, two differently-pruned variants;
    // /v1/infer routes by name with bit-exact parity against a
    // dedicated single-model pool for each, and /metrics reports them
    // under distinct model labels.
    let (server, state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let addr = server.local_addr().to_string();
    let fast_ref = dedicated_pool(FAST_SPEC);
    let accurate_ref = dedicated_pool(ACCURATE_SPEC);
    let per = fast_ref.input_elems_per_image;
    assert_eq!(per, accurate_ref.input_elems_per_image);

    // Concurrent clients, each pinned to one variant, interleaving on
    // the wire.
    let handles: Vec<_> = [("fast", 0u64), ("accurate", 1), ("fast", 2), ("accurate", 3)]
        .into_iter()
        .map(|(model, seed)| {
            let addr = addr.clone();
            std::thread::spawn(move || -> Vec<(Vec<f32>, Vec<f32>, usize)> {
                let mut client =
                    HttpClient::connect(&addr, Duration::from_secs(10)).expect("client");
                synthetic_images(3, per, 100 + seed)
                    .into_iter()
                    .map(|img| {
                        let resp = client
                            .post("/v1/infer", &image_body_for(model, &img))
                            .expect("mixed infer");
                        assert_eq!(resp.status, 200, "model {} must answer", model);
                        let j = resp.json().expect("json");
                        assert_eq!(
                            j.get("model").and_then(|v| v.as_str()),
                            Some(model),
                            "response must echo the routed model"
                        );
                        let argmax = j
                            .get("predicted_class")
                            .and_then(|v| v.as_usize())
                            .expect("argmax");
                        (img, logits_of(&j), argmax)
                    })
                    .collect()
            })
        })
        .collect();
    for (w, h) in handles.into_iter().enumerate() {
        let reference = if w % 2 == 0 { &fast_ref } else { &accurate_ref };
        for (i, (img, got, argmax)) in h.join().expect("client thread").into_iter().enumerate()
        {
            let want = reference.infer(img).expect("dedicated pool infer");
            assert_eq!(
                got, want.logits,
                "client {} image {}: HTTP logits != dedicated pool logits",
                w, i
            );
            assert_eq!(argmax, want.predicted_class);
        }
    }
    // The two variants are genuinely different models.
    let probe = synthetic_images(1, per, 999).remove(0);
    let a = fast_ref.infer(probe.clone()).expect("fast ref").logits;
    let b = accurate_ref.infer(probe).expect("accurate ref").logits;
    assert_ne!(a, b, "differently-pruned variants must disagree somewhere");

    // Per-model metric labels, with the right per-model request counts.
    let mut client = client_for(&server);
    let scrape = String::from_utf8(client.get("/metrics").expect("scrape").body)
        .expect("exposition is UTF-8");
    for model in ["fast", "accurate"] {
        let line = format!("vitfpga_pool_requests_total{{model=\"{}\"}}", model);
        let v = prom_value(&scrape, &line)
            .unwrap_or_else(|| panic!("missing {} in scrape:\n{}", line, scrape));
        assert_eq!(v, 6.0, "each variant answered 2 clients x 3 requests");
        assert_eq!(
            prom_value(&scrape, &format!("vitfpga_model_ready{{model=\"{}\"}}", model)),
            Some(1.0),
            "{} must be ready after traffic",
            model
        );
    }
    drop(state);
}

#[test]
fn unknown_model_maps_to_404_and_models_route_lists_variants() {
    let (server, _state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let mut client = client_for(&server);

    // Unknown model: 404 with the registered names in the body.
    let resp = client
        .post("/v1/infer", &image_body_for("nope", &[0.0; 4]))
        .expect("http exchange");
    assert_eq!(resp.status, 404, "unknown model must 404, not 400/503");
    let j = resp.json().expect("404 body is JSON");
    let known: Vec<&str> = j
        .get("models")
        .and_then(|m| m.as_arr())
        .expect("404 lists registered models")
        .iter()
        .filter_map(|v| v.as_str())
        .collect();
    assert_eq!(known, vec!["fast", "accurate"], "registration order preserved");
    // A non-string model field is a 400, not a 404.
    assert_eq!(
        client
            .post("/v1/infer", b"{\"model\": 3, \"image\": [0]}")
            .expect("http exchange")
            .status,
        400
    );

    // /v1/models enumerates both variants with specs and readiness.
    let resp = client.get("/v1/models").expect("models route");
    assert_eq!(resp.status, 200);
    let j = resp.json().expect("models body is JSON");
    assert_eq!(j.get("default").and_then(|v| v.as_str()), Some("fast"));
    let models = j.get("models").and_then(|m| m.as_arr()).expect("models array");
    assert_eq!(models.len(), 2);
    assert_eq!(models[0].get("name").and_then(|v| v.as_str()), Some("fast"));
    assert_eq!(models[0].get("spec").and_then(|v| v.as_str()), Some(FAST_SPEC));
    assert_eq!(models[0].get("default").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(models[1].get("name").and_then(|v| v.as_str()), Some("accurate"));
    assert_eq!(models[1].get("default").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(
        models[1].get("input_elems_per_image").and_then(|v| v.as_usize()),
        Some(32 * 32 * 3),
        "shape known even for cold models"
    );
    // Wrong method on the new route.
    assert_eq!(client.post("/v1/models", b"{}").expect("405").status, 405);
}

#[test]
fn models_build_lazily_on_first_request() {
    let (server, state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let mut client = client_for(&server);

    // Registration alone must not construct pools: healthz says cold,
    // metrics carries ready=0 and no pool samples yet.
    let health = client.get("/healthz").expect("healthz").json().expect("json");
    assert_eq!(
        health.at(&["models", "fast", "status"]).and_then(|v| v.as_str()),
        Some("cold")
    );
    assert_eq!(health.get("status").and_then(|v| v.as_str()), Some("ok"),
               "cold models are healthy, not dead");
    let scrape = String::from_utf8(client.get("/metrics").expect("scrape").body).unwrap();
    assert_eq!(
        prom_value(&scrape, "vitfpga_model_ready{model=\"fast\"}"),
        Some(0.0),
        "scrapes must not cold-start models"
    );
    assert!(!state.registry.is_ready("fast"));

    // First request for one variant builds exactly that variant.
    let img = synthetic_images(1, 32 * 32 * 3, 4).remove(0);
    let resp = client
        .post("/v1/infer", &image_body_for("fast", &img))
        .expect("first fast request");
    assert_eq!(resp.status, 200);
    assert!(state.registry.is_ready("fast"), "first request constructs the pool");
    assert!(!state.registry.is_ready("accurate"), "the other variant stays cold");
    let health = client.get("/healthz").expect("healthz").json().expect("json");
    assert_eq!(
        health.at(&["models", "fast", "status"]).and_then(|v| v.as_str()),
        Some("ok")
    );
    assert_eq!(
        health.at(&["models", "accurate", "status"]).and_then(|v| v.as_str()),
        Some("cold")
    );
}

#[test]
fn loadgen_model_mix_drives_both_models() {
    // The CI registry smoke, in-process: two synth variants served,
    // weighted mixed-model loadgen traffic, both models visible in the
    // scrape afterwards.
    use vitfpga::server::{loadgen, LoadMode, LoadgenConfig};
    let (server, state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        mode: LoadMode::Closed,
        concurrency: 4,
        requests: 48,
        batch: 1,
        timeout: Duration::from_secs(10),
        seed: 11,
        models: vec![("fast".to_string(), 3.0), ("accurate".to_string(), 1.0)],
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(&cfg).expect("mixed loadgen run");
    assert_eq!(report.sent, 48);
    assert_eq!(report.ok, 48, "no sheds/errors at queue 64: {}", report);
    let per: std::collections::BTreeMap<_, _> = report.per_model.iter().cloned().collect();
    let fast_ok = per.get("fast").copied().unwrap_or(0);
    let accurate_ok = per.get("accurate").copied().unwrap_or(0);
    assert_eq!(fast_ok + accurate_ok, 48, "per-model tallies partition the run");
    assert!(fast_ok > 0 && accurate_ok > 0, "both variants must see traffic");
    assert!(
        fast_ok > accurate_ok,
        "3:1 weights over 48 requests should favour 'fast' ({} vs {})",
        fast_ok,
        accurate_ok
    );

    // Both models answered real inferences, attributed separately.
    let mut client = client_for(&server);
    let scrape =
        String::from_utf8(client.get("/metrics").expect("scrape").body).expect("UTF-8");
    for (model, ok) in [("fast", fast_ok), ("accurate", accurate_ok)] {
        let v = prom_value(
            &scrape,
            &format!("vitfpga_pool_requests_total{{model=\"{}\"}}", model),
        )
        .unwrap_or_else(|| panic!("no labelled counter for {}:\n{}", model, scrape));
        assert_eq!(v, ok as f64, "pool counter for {} matches the client tally", model);
    }
    // Loadgen answered an unknown mix target with a clean error.
    let bad = LoadgenConfig {
        models: vec![("nope".to_string(), 1.0)],
        ..cfg
    };
    let err = loadgen::run(&bad).expect_err("unknown model target must fail fast");
    assert!(
        format!("{:#}", err).contains("nope"),
        "error should name the unknown model: {:#}",
        err
    );
    drop(state);
}

#[test]
fn graceful_shutdown_drains_in_flight_before_socket_closes() {
    graceful_drain_on(EdgeKind::Threaded);
}

#[test]
fn graceful_drain_evented() {
    graceful_drain_on(EdgeKind::Evented);
}

fn graceful_drain_on(edge: EdgeKind) {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(300) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("slow pool start");
    let (mut server, _state) = serve_on(edge, pool, None, HttpConfig::default());
    let addr = server.local_addr();

    // A request that will still be executing when shutdown starts.
    let worker = std::thread::spawn(move || {
        let mut client =
            HttpClient::connect(&addr.to_string(), Duration::from_secs(10)).expect("client");
        client.post("/v1/infer", &image_body(&[5.0, 0.0]))
    });
    // Wait until the server has parsed it (it is now in flight).
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.in_flight() == 0 {
        assert!(Instant::now() < deadline, "request never became in-flight");
        std::thread::sleep(Duration::from_millis(2));
    }

    server.shutdown();

    // The in-flight request was answered, not reset.
    let resp = worker.join().expect("client thread").expect("drained response");
    assert_eq!(resp.status, 200, "in-flight request must complete through the drain");
    let j = resp.json().expect("drained body is JSON");
    assert_eq!(logits_of(&j), vec![5.0, 6.0, 7.0, 8.0]);

    // And only after the drain did the socket close.
    let refused = TcpStream::connect_timeout(&addr, Duration::from_millis(500));
    assert!(refused.is_err(), "listener must be closed after shutdown");
}

#[test]
fn concurrent_keep_alive_clients_all_answered() {
    concurrent_keep_alive_on(EdgeKind::Threaded);
}

#[test]
fn concurrent_keep_alive_evented() {
    concurrent_keep_alive_on(EdgeKind::Evented);
}

fn concurrent_keep_alive_on(edge: EdgeKind) {
    // The acceptance-bar smoke: N concurrent keep-alive clients, each
    // issuing several requests, all answered correctly by the pool.
    let (server, state) = serve_on(edge, native_pool(2), None, HttpConfig::default());
    let pool = pool_of(&state);
    let per = pool.input_elems_per_image;
    let addr = server.local_addr().to_string();
    let want = pool
        .infer(synthetic_images(1, per, 21).remove(0))
        .expect("reference infer")
        .logits;

    let handles: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.clone();
            let want = want.clone();
            std::thread::spawn(move || {
                let mut client =
                    HttpClient::connect(&addr, Duration::from_secs(10)).expect("client");
                // Learn the model shape the way loadgen does.
                let health = client.get("/healthz").expect("healthz").json().expect("json");
                let per = health
                    .get("input_elems_per_image")
                    .and_then(|v| v.as_usize())
                    .expect("shape");
                let img = synthetic_images(1, per, 21).remove(0);
                for _ in 0..4 {
                    let resp = client.post("/v1/infer", &image_body(&img)).expect("infer");
                    assert_eq!(resp.status, 200);
                    assert_eq!(logits_of(&resp.json().expect("json")), want);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let m = pool.metrics().expect("pool metrics");
    assert!(m.pool.requests >= 24, "all 6x4 HTTP requests reached the pool");
}

// ---------------------------------------------------------------------------
// transport: pipelining and the connection cap (both edges)
// ---------------------------------------------------------------------------

/// Read `n` `Content-Length`-framed responses off one raw socket.
fn read_responses(stream: &mut TcpStream, n: usize) -> Vec<(u16, Vec<u8>)> {
    let mut buf: Vec<u8> = Vec::new();
    let mut out = Vec::new();
    let mut chunk = [0u8; 4096];
    let deadline = Instant::now() + Duration::from_secs(10);
    while out.len() < n {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            let head = String::from_utf8_lossy(&buf[..pos]).to_string();
            let status: u16 = head
                .lines()
                .next()
                .and_then(|l| l.split(' ').nth(1))
                .and_then(|s| s.parse().ok())
                .expect("status line");
            let clen: usize = head
                .lines()
                .filter_map(|l| l.split_once(':'))
                .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
                .and_then(|(_, v)| v.trim().parse().ok())
                .unwrap_or(0);
            if buf.len() >= pos + 4 + clen {
                let body = buf[pos + 4..pos + 4 + clen].to_vec();
                buf.drain(..pos + 4 + clen);
                out.push((status, body));
                continue;
            }
        }
        assert!(Instant::now() < deadline, "timed out with {} of {} responses", out.len(), n);
        match stream.read(&mut chunk) {
            Ok(0) => panic!("connection closed with {} of {} responses", out.len(), n),
            Ok(k) => buf.extend_from_slice(&chunk[..k]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => panic!("read error: {}", e),
        }
    }
    out
}

#[test]
fn pipelined_requests_answered_in_order() {
    pipelined_on(EdgeKind::Threaded);
}

#[test]
fn pipelined_requests_evented() {
    pipelined_on(EdgeKind::Evented);
}

fn pipelined_on(edge: EdgeKind) {
    // Two requests written back-to-back before reading anything: both
    // must answer, in request order, on the same connection.
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let (server, _state) = serve_on(edge, pool, None, HttpConfig::default());
    let mut stream = TcpStream::connect(server.local_addr()).expect("raw connect");
    stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .expect("raw read timeout");
    let mut wire = Vec::new();
    for x in [1.0f32, 2.0] {
        let body = image_body(&[x, 0.0]);
        wire.extend_from_slice(
            format!(
                "POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\n\r\n",
                body.len()
            )
            .as_bytes(),
        );
        wire.extend_from_slice(&body);
    }
    stream.write_all(&wire).expect("pipelined write");
    let responses = read_responses(&mut stream, 2);
    for (i, ((status, body), want0)) in responses.iter().zip([1.0f32, 2.0]).enumerate() {
        assert_eq!(*status, 200, "pipelined response {}", i);
        let j = Json::parse(std::str::from_utf8(body).expect("UTF-8")).expect("JSON");
        assert_eq!(
            logits_of(&j)[0],
            want0,
            "response {} must come back in request order",
            i
        );
    }
}

#[test]
fn connection_cap_answers_503_with_retry_after() {
    connection_cap_on(EdgeKind::Threaded);
}

#[test]
fn connection_cap_evented() {
    connection_cap_on(EdgeKind::Evented);
}

fn connection_cap_on(edge: EdgeKind) {
    let config = HttpConfig { max_connections: 1, ..HttpConfig::default() };
    let (server, state) = serve_on(edge, native_pool(1), None, config);
    let mut client = client_for(&server);
    // This keep-alive connection holds the only slot.
    assert_eq!(client.get("/healthz").expect("healthz").status, 200);

    // An over-cap connection is answered, not silently dropped: 503
    // with Retry-After, then closed.
    let mut over = TcpStream::connect(server.local_addr()).expect("overflow connect");
    over.set_read_timeout(Some(Duration::from_secs(5)))
        .expect("overflow read timeout");
    let mut text = String::new();
    over.read_to_string(&mut text).expect("read 503 then EOF");
    assert!(
        text.starts_with("HTTP/1.1 503 "),
        "over-cap connection must get 503, got: {}",
        text.lines().next().unwrap_or("")
    );
    assert!(
        text.to_ascii_lowercase().contains("retry-after: 1"),
        "503 must carry Retry-After:\n{}",
        text
    );

    // Counted in /metrics, and the in-cap connection still serves.
    let scrape =
        String::from_utf8(client.get("/metrics").expect("scrape").body).expect("UTF-8");
    assert_eq!(
        prom_value(&scrape, "vitfpga_http_open_connections"),
        Some(1.0),
        "exactly the keep-alive connection is open:\n{}",
        scrape
    );
    assert!(
        prom_value(&scrape, "vitfpga_http_conn_overflow_total").unwrap_or(0.0) >= 1.0,
        "overflow counter must advance:\n{}",
        scrape
    );
    drop(state);
}

// ---------------------------------------------------------------------------
// binary tensor wire format
// ---------------------------------------------------------------------------

fn f32s_le(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn binary_image_bytes(img: &[f32]) -> Vec<u8> {
    img.iter().flat_map(|v| v.to_le_bytes()).collect()
}

#[test]
fn binary_round_trip_matches_json_bit_for_bit() {
    binary_round_trip_on(EdgeKind::Threaded);
}

#[test]
fn binary_round_trip_evented() {
    binary_round_trip_on(EdgeKind::Evented);
}

fn binary_round_trip_on(edge: EdgeKind) {
    let (server, state) = serve_on(edge, native_pool(1), None, HttpConfig::default());
    let per = pool_of(&state).input_elems_per_image;
    let mut client = client_for(&server);
    let img = synthetic_images(1, per, 31).remove(0);

    // JSON reference answer for the same image.
    let json_resp = client.post("/v1/infer", &image_body(&img)).expect("json infer");
    assert_eq!(json_resp.status, 200);
    let j = json_resp.json().expect("json body");
    let want = logits_of(&j);

    // Binary both ways: raw LE f32 request, Accept binary.
    let resp = client
        .post_with(
            "/v1/infer",
            &binary_image_bytes(&img),
            BINARY_CONTENT_TYPE,
            Some(BINARY_CONTENT_TYPE),
        )
        .expect("binary infer");
    assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
    assert_eq!(resp.header("content-type"), Some(BINARY_CONTENT_TYPE));
    let got = f32s_le(&resp.body);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "logit {}: binary wire differs from JSON ({} vs {})",
            i,
            g,
            w
        );
    }
    // Response metadata rides in headers instead of the JSON envelope.
    let class: usize = resp
        .header("x-vitfpga-predicted-class")
        .expect("class header")
        .parse()
        .expect("class parses");
    assert_eq!(Some(class), j.get("predicted_class").and_then(|v| v.as_usize()));
    let latency: f64 = resp
        .header("x-vitfpga-latency-ms")
        .expect("latency header")
        .parse()
        .expect("latency parses");
    assert!(latency >= 0.0);
}

#[test]
fn binary_batch_round_trip_matches_json() {
    let (server, state) = serve(native_pool(2), None, HttpConfig::default());
    let per = pool_of(&state).input_elems_per_image;
    let mut client = client_for(&server);
    let imgs = synthetic_images(3, per, 37);

    let json_resp = client
        .post("/v1/infer_batch", &images_body(&imgs))
        .expect("json batch");
    assert_eq!(json_resp.status, 200);
    let j = json_resp.json().expect("json");
    let results = j.get("results").and_then(|r| r.as_arr()).expect("results array");

    let flat: Vec<u8> = imgs.iter().flat_map(|i| binary_image_bytes(i)).collect();
    let resp = client
        .post_with("/v1/infer_batch", &flat, BINARY_CONTENT_TYPE, Some(BINARY_CONTENT_TYPE))
        .expect("binary batch");
    assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
    assert_eq!(resp.header("x-vitfpga-count"), Some("3"));
    let got = f32s_le(&resp.body);
    let want: Vec<f32> = results.iter().flat_map(logits_of).collect();
    assert_eq!(got.len(), want.len(), "concatenated logits cover every image");
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "flat logit {} differs between wire formats", i);
    }
    // Per-image argmaxes ride in one comma-joined header.
    let classes: Vec<usize> = resp
        .header("x-vitfpga-predicted-classes")
        .expect("classes header")
        .split(',')
        .map(|s| s.parse().expect("class"))
        .collect();
    let want_classes: Vec<usize> = results
        .iter()
        .map(|r| r.get("predicted_class").and_then(|v| v.as_usize()).expect("argmax"))
        .collect();
    assert_eq!(classes, want_classes);
}

#[test]
fn wire_format_negotiation_is_independent_per_direction() {
    let (server, state) = serve(native_pool(1), None, HttpConfig::default());
    let per = pool_of(&state).input_elems_per_image;
    let mut client = client_for(&server);
    let img = synthetic_images(1, per, 41).remove(0);
    let want = {
        let r = client.post("/v1/infer", &image_body(&img)).expect("reference");
        logits_of(&r.json().expect("json"))
    };

    // Binary in, JSON out (no Accept header).
    let resp = client
        .post_with("/v1/infer", &binary_image_bytes(&img), BINARY_CONTENT_TYPE, None)
        .expect("binary request, json response");
    assert_eq!(resp.status, 200);
    assert!(
        resp.header("content-type").unwrap_or("").starts_with("application/json"),
        "without Accept the response stays JSON"
    );
    assert_eq!(logits_of(&resp.json().expect("json")), want);

    // JSON in, binary out (Accept lists binary among alternatives).
    let accept = format!("text/html, {}", BINARY_CONTENT_TYPE);
    let resp = client
        .post_with("/v1/infer", &image_body(&img), "application/json", Some(&accept))
        .expect("json request, binary response");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("content-type"), Some(BINARY_CONTENT_TYPE));
    let got_bits: Vec<u32> = f32s_le(&resp.body).iter().map(|v| v.to_bits()).collect();
    let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got_bits, want_bits);

    // Errors stay JSON even when the client accepts binary.
    let resp = client
        .post_with("/v1/infer", &[1, 2, 3], BINARY_CONTENT_TYPE, Some(BINARY_CONTENT_TYPE))
        .expect("truncated body");
    assert_eq!(resp.status, 400);
    assert!(
        resp.header("content-type").unwrap_or("").starts_with("application/json"),
        "error bodies are always JSON"
    );
    resp.json().expect("error body parses as JSON");
}

#[test]
fn binary_framing_errors_map_to_400_and_413() {
    // EchoBackend: 2 f32 per image = 8 bytes; a tiny transport cap
    // exercises the 400 (bad framing) vs 413 (too large) split.
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let config = HttpConfig { max_body_bytes: 64, ..HttpConfig::default() };
    let (server, _state) = serve(pool, None, config);
    let mut client = client_for(&server);
    let good = binary_image_bytes(&[1.0, 2.0]);

    // Truncated: 7 of 8 bytes.
    let resp = client
        .post_with("/v1/infer", &good[..7], BINARY_CONTENT_TYPE, None)
        .expect("truncated");
    assert_eq!(resp.status, 400, "truncated binary body must 400");
    // Extra trailing bytes (within the cap) are a framing error too.
    let resp = client
        .post_with("/v1/infer", &binary_image_bytes(&[1.0, 2.0, 3.0]), BINARY_CONTENT_TYPE, None)
        .expect("overlong");
    assert_eq!(resp.status, 400, "single-image body with extra bytes must 400");
    // Batch: not a multiple of the image stride / empty.
    let resp = client
        .post_with("/v1/infer_batch", &good[..6], BINARY_CONTENT_TYPE, None)
        .expect("ragged batch");
    assert_eq!(resp.status, 400);
    let resp = client
        .post_with("/v1/infer_batch", b"", BINARY_CONTENT_TYPE, None)
        .expect("empty batch");
    assert_eq!(resp.status, 400);

    // Over the transport cap: 413 before buffering.
    let big = vec![0u8; 65 * 4];
    let resp = client
        .post_with("/v1/infer", &big, BINARY_CONTENT_TYPE, None)
        .expect("oversized");
    assert_eq!(resp.status, 413);
    // The reject closed the connection; the client reconnects and a
    // well-formed binary request still answers exactly.
    let ok = client
        .post_with("/v1/infer", &good, BINARY_CONTENT_TYPE, Some(BINARY_CONTENT_TYPE))
        .expect("follow-up");
    assert_eq!(ok.status, 200);
    assert_eq!(f32s_le(&ok.body), vec![1.0, 2.0, 3.0, 4.0]);
}

#[test]
fn mixed_encodings_share_one_keep_alive_connection() {
    mixed_encodings_on(EdgeKind::Threaded);
}

#[test]
fn mixed_encodings_evented() {
    mixed_encodings_on(EdgeKind::Evented);
}

fn mixed_encodings_on(edge: EdgeKind) {
    let (server, state) = serve_on(edge, native_pool(1), None, HttpConfig::default());
    let per = pool_of(&state).input_elems_per_image;
    let mut client = client_for(&server);
    let img = synthetic_images(1, per, 51).remove(0);
    let mut reference: Option<Vec<u32>> = None;
    for round in 0..3 {
        // JSON then binary, alternating on the same connection.
        let j = client.post("/v1/infer", &image_body(&img)).expect("json round");
        assert_eq!(j.status, 200, "round {}", round);
        let json_bits: Vec<u32> =
            logits_of(&j.json().expect("json")).iter().map(|v| v.to_bits()).collect();
        let b = client
            .post_with(
                "/v1/infer",
                &binary_image_bytes(&img),
                BINARY_CONTENT_TYPE,
                Some(BINARY_CONTENT_TYPE),
            )
            .expect("binary round");
        assert_eq!(b.status, 200, "round {}", round);
        let bin_bits: Vec<u32> = f32s_le(&b.body).iter().map(|v| v.to_bits()).collect();
        assert_eq!(json_bits, bin_bits, "round {}: encodings disagree", round);
        match &reference {
            Some(r) => assert_eq!(r, &bin_bits, "round {}: answers drift across rounds", round),
            None => reference = Some(bin_bits),
        }
    }
    // All six requests rode one client connection.
    assert_eq!(client.connections(), 1, "mixed encodings must not force reconnects");
}

#[test]
fn binary_query_param_routes_named_models() {
    let (server, _state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let fast_ref = dedicated_pool(FAST_SPEC);
    let per = fast_ref.input_elems_per_image;
    let mut client = client_for(&server);
    let img = synthetic_images(1, per, 61).remove(0);

    let resp = client
        .post_with(
            "/v1/infer?model=fast",
            &binary_image_bytes(&img),
            BINARY_CONTENT_TYPE,
            Some(BINARY_CONTENT_TYPE),
        )
        .expect("named binary infer");
    assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
    assert_eq!(resp.header("x-vitfpga-model"), Some("fast"));
    let want = fast_ref.infer(img).expect("dedicated pool infer").logits;
    assert_eq!(f32s_le(&resp.body), want, "query-param routing hits the named variant");

    // Unknown names still 404 with a JSON error body.
    let other = synthetic_images(1, per, 62).remove(0);
    let resp = client
        .post_with("/v1/infer?model=nope", &binary_image_bytes(&other), BINARY_CONTENT_TYPE, None)
        .expect("unknown model");
    assert_eq!(resp.status, 404);
    resp.json().expect("404 body is JSON");
}

#[test]
fn loadgen_binary_wire_and_connection_accounting() {
    use vitfpga::server::{loadgen, LoadMode, LoadgenConfig, WireFormat};
    let (server, state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        mode: LoadMode::Closed,
        concurrency: 3,
        requests: 24,
        batch: 1,
        timeout: Duration::from_secs(10),
        seed: 13,
        models: vec![("fast".to_string(), 1.0), ("accurate".to_string(), 1.0)],
        wire: WireFormat::Binary,
    };
    let report = loadgen::run(&cfg).expect("binary loadgen run");
    assert_eq!(report.ok, 24, "binary wire must answer everything: {}", report);
    let per: std::collections::BTreeMap<_, _> = report.per_model.iter().cloned().collect();
    assert!(
        per.get("fast").copied().unwrap_or(0) > 0
            && per.get("accurate").copied().unwrap_or(0) > 0,
        "both variants must see binary traffic: {}",
        report
    );
    // Transport-health accounting: one keep-alive connection per
    // worker, none forcibly reconnected.
    assert_eq!(report.connections, 3, "one connection per worker: {}", report);
    assert_eq!(report.reconnects, 0);
    let j = report.to_json();
    assert_eq!(j.get("connections").and_then(|v| v.as_f64()), Some(3.0));
    assert_eq!(j.get("reconnects").and_then(|v| v.as_f64()), Some(0.0));
    assert!(j.get("reconnect_rate_per_s").and_then(|v| v.as_f64()).is_some());
    drop(state);
}

// ---------------------------------------------------------------------------
// strict framing + encoded query params + stalled-writer hardening
// ---------------------------------------------------------------------------

/// Send raw request bytes on a fresh connection, read until the peer
/// closes (tolerating a reset once bytes have arrived — reject paths
/// close immediately after answering), and return the status line.
fn raw_status_line(addr: std::net::SocketAddr, wire: &[u8]) -> String {
    let mut stream = TcpStream::connect(addr).expect("raw connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .expect("raw read timeout");
    stream.write_all(wire).expect("raw write");
    let mut response = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => response.extend_from_slice(&chunk[..n]),
            Err(_) if !response.is_empty() => break,
            Err(e) => panic!("raw read produced nothing: {}", e),
        }
    }
    String::from_utf8_lossy(&response)
        .lines()
        .next()
        .unwrap_or("")
        .to_string()
}

#[test]
fn content_length_smuggling_vectors_rejected() {
    content_length_strictness_on(EdgeKind::Threaded);
}

#[test]
fn content_length_smuggling_vectors_rejected_evented() {
    content_length_strictness_on(EdgeKind::Evented);
}

fn content_length_strictness_on(edge: EdgeKind) {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let (server, _state) = serve_on(edge, pool, None, HttpConfig::default());
    let addr = server.local_addr();

    // Conflicting duplicate Content-Length headers: a proxy that
    // honours the other copy would smuggle a second request. No body
    // bytes are sent — rejection happens at header parse, and unread
    // body bytes could turn the server's close into a reset.
    let line = raw_status_line(
        addr,
        b"POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Length: 2\r\nContent-Length: 4\r\n\r\n",
    );
    assert!(line.starts_with("HTTP/1.1 400 "), "conflicting lengths: {}", line);

    // `usize::parse` alone would accept a leading '+'; strict digits
    // only.
    let line = raw_status_line(
        addr,
        b"POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Length: +2\r\n\r\n",
    );
    assert!(line.starts_with("HTTP/1.1 400 "), "signed length: {}", line);

    // Duplicate but *agreeing* Content-Length headers stay acceptable
    // (RFC 7230 lets them collapse to one value).
    let line = raw_status_line(
        addr,
        b"GET /healthz HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\nContent-Length: 0\r\nConnection: close\r\n\r\n",
    );
    assert!(line.starts_with("HTTP/1.1 200 "), "agreeing duplicates: {}", line);
}

#[test]
fn percent_encoded_model_query_param_decodes() {
    let (server, _state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let fast_ref = dedicated_pool(FAST_SPEC);
    let per = fast_ref.input_elems_per_image;
    let mut client = client_for(&server);
    let img = synthetic_images(1, per, 63).remove(0);

    // "fa%73t" percent-decodes to "fast" and must route identically.
    let resp = client
        .post_with(
            "/v1/infer?model=fa%73t",
            &binary_image_bytes(&img),
            BINARY_CONTENT_TYPE,
            Some(BINARY_CONTENT_TYPE),
        )
        .expect("encoded model infer");
    assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
    assert_eq!(resp.header("x-vitfpga-model"), Some("fast"));
    let want = fast_ref.infer(img).expect("dedicated pool infer").logits;
    assert_eq!(f32s_le(&resp.body), want, "decoded name hits the same variant");

    // A '+' decodes to a space — no such model, clean 404 (not a
    // silent fall-through to the default model).
    let other = synthetic_images(1, per, 64).remove(0);
    let resp = client
        .post_with("/v1/infer?model=fa+st", &binary_image_bytes(&other), BINARY_CONTENT_TYPE, None)
        .expect("spaced model");
    assert_eq!(resp.status, 404);
    resp.json().expect("404 body is JSON");
}

/// Echo-shaped backend whose responses are tens of MB (one f32 per
/// "class"), enough to overrun loopback socket buffering so a client
/// that never reads its response parks the connection mid-write.
struct WideBackend;

impl Backend for WideBackend {
    fn name(&self) -> &str {
        "wide"
    }
    fn batch_capacity(&self) -> usize {
        1
    }
    fn num_classes(&self) -> usize {
        6_000_000
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, _flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        let nc = self.num_classes();
        for i in 0..batch {
            for j in 0..nc {
                out[i * nc + j] = (j % 8) as f32 + 0.5;
            }
        }
        Ok(())
    }
}

#[test]
fn evented_shutdown_survives_stalled_response_writer() {
    // A client that sends a request and then never reads the response
    // leaves the connection parked in its write phase (the socket never
    // turns writable once the kernel buffers fill). Shutdown must still
    // complete: the write-stall sweep closes the connection, and the
    // loop exits unconditionally once the drain deadline elapses.
    let pool = BackendPool::start(
        |_i| Ok(WideBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 4 },
    )
    .expect("wide pool start");
    let config = HttpConfig {
        read_deadline: Duration::from_millis(400),
        drain_deadline: Duration::from_millis(700),
        ..HttpConfig::default()
    };
    let (mut server, _state) = serve_on(EdgeKind::Evented, pool, None, config);
    let addr = server.local_addr();

    // Raw socket: binary request (binary Accept keeps the 24 MB
    // response allocation-light), then stop reading entirely.
    let mut stream = TcpStream::connect(addr).expect("stalling client connect");
    let body = binary_image_bytes(&[5.0, 0.0]);
    let head = format!(
        "POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Type: {ct}\r\nAccept: {ct}\r\nContent-Length: {len}\r\n\r\n",
        ct = BINARY_CONTENT_TYPE,
        len = body.len(),
    );
    stream.write_all(head.as_bytes()).expect("stall head");
    stream.write_all(&body).expect("stall body");

    // Wait until the request is in flight; it stays in flight while the
    // response write is wedged against our unread socket.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.in_flight() == 0 {
        assert!(Instant::now() < deadline, "request never became in-flight");
        std::thread::sleep(Duration::from_millis(5));
    }
    // Give the handler time to finish and the write to stall.
    std::thread::sleep(Duration::from_millis(300));

    let begun = Instant::now();
    server.shutdown();
    assert!(
        begun.elapsed() < Duration::from_secs(8),
        "shutdown must not hang on a peer that never reads its response"
    );
    drop(stream);
}

#[test]
fn evented_survives_peer_vanishing_mid_dispatch() {
    // A peer that disconnects while its request is still with the
    // handler must not wedge the loop or leak in-flight counts: the
    // ERR/HUP event closes the connection and the late completion is
    // dropped.
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(300) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("slow pool start");
    let (server, state) = serve_on(EdgeKind::Evented, pool, None, HttpConfig::default());
    let addr = server.local_addr();

    {
        let mut stream = TcpStream::connect(addr).expect("vanishing client connect");
        let body = image_body(&[5.0, 0.0]);
        let head = format!(
            "POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        stream.write_all(head.as_bytes()).expect("head");
        stream.write_all(&body).expect("body");
        // Wait for dispatch, then vanish.
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.in_flight() == 0 {
            assert!(Instant::now() < deadline, "request never became in-flight");
            std::thread::sleep(Duration::from_millis(2));
        }
    } // stream dropped: RST/FIN while the handler is still sleeping

    // The in-flight span settles (either on the hangup event or when
    // the completed response fails to write), and the server keeps
    // serving other clients.
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.in_flight() != 0 {
        assert!(Instant::now() < deadline, "in-flight count leaked after peer vanished");
        std::thread::sleep(Duration::from_millis(10));
    }
    let mut client = client_for(&server);
    let resp = client.post("/v1/infer", &image_body(&[7.0, 0.0])).expect("later request");
    assert_eq!(resp.status, 200, "server must keep serving after an abandoned dispatch");
    let j = resp.json().expect("json");
    assert_eq!(logits_of(&j), vec![7.0, 8.0, 9.0, 10.0]);
    drop(state);
}
