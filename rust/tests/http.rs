//! Integration battery for the HTTP serving edge, driven over real
//! loopback sockets: response parity with direct `BackendPool::infer`,
//! typed-error -> status-code mapping (429 shed with a computed
//! `Retry-After`, 504 deadline, 404 unknown model), mixed-model
//! routing through the registry (per-model parity with dedicated
//! pools, `model="..."` metric labels, `--model-mix` loadgen),
//! malformed/oversized body rejection, Prometheus scrape
//! well-formedness with advancing counters, keep-alive reuse, and
//! graceful drain-on-shutdown. Runs with the default feature set — no
//! artifacts, no XLA toolchain, no non-std dependencies.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use vitfpga::backend::{Backend, NativeBackend};
use vitfpga::config::{PruningSetting, TEST_TINY};
use vitfpga::coordinator::{BackendPool, BatchPolicy, PoolPolicy};
use vitfpga::funcsim::Precision;
use vitfpga::registry::{ModelSpec, Registry};
use vitfpga::server::{route, AppState, HttpClient, HttpConfig, HttpRequest, HttpServer};
use vitfpga::util::json::Json;
use vitfpga::util::rng::Rng;

const SEED: u64 = 42;

/// Deterministic instant backend: logits[j] = image[0] + j.
struct EchoBackend;

impl Backend for EchoBackend {
    fn name(&self) -> &str {
        "echo"
    }
    fn batch_capacity(&self) -> usize {
        8
    }
    fn num_classes(&self) -> usize {
        4
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        for i in 0..batch {
            for j in 0..4 {
                out[i * 4 + j] = flat[i * 2] + j as f32;
            }
        }
        Ok(())
    }
}

/// Echo with a per-batch delay — widens in-flight windows so shed,
/// deadline and drain behaviour are deterministic.
struct SlowBackend {
    delay: Duration,
}

impl Backend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }
    fn batch_capacity(&self) -> usize {
        8
    }
    fn num_classes(&self) -> usize {
        4
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        std::thread::sleep(self.delay);
        for i in 0..batch {
            for j in 0..4 {
                out[i * 4 + j] = flat[i * 2] + j as f32;
            }
        }
        Ok(())
    }
}

fn batch_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }
}

fn native_pool(replicas: usize) -> BackendPool {
    BackendPool::start(
        |_i| NativeBackend::synthetic(&TEST_TINY, &PruningSetting::new(8, 0.7, 0.7), SEED, Precision::F32),
        PoolPolicy { replicas, batch: batch_policy(), queue_capacity: 64 },
    )
    .expect("native pool start")
}

/// Boot a server on an ephemeral loopback port over `pool`.
fn serve(
    pool: BackendPool,
    timeout: Option<Duration>,
    config: HttpConfig,
) -> (HttpServer, Arc<AppState>) {
    serve_registry(Registry::single(pool), timeout, config)
}

/// Boot a server over a full model registry.
fn serve_registry(
    registry: Registry,
    timeout: Option<Duration>,
    config: HttpConfig,
) -> (HttpServer, Arc<AppState>) {
    let state = Arc::new(AppState::with_registry(registry, timeout));
    let handler_state = Arc::clone(&state);
    let server = HttpServer::start("127.0.0.1:0", config, move |req: &HttpRequest| {
        route(&handler_state, req)
    })
    .expect("http server start");
    (server, state)
}

/// The state's default-model pool (always prebuilt in these tests).
fn pool_of(state: &AppState) -> Arc<BackendPool> {
    state.default_pool().expect("default pool")
}

fn client_for(server: &HttpServer) -> HttpClient {
    HttpClient::connect(&server.local_addr().to_string(), Duration::from_secs(10))
        .expect("client connect")
}

fn image_body(img: &[f32]) -> Vec<u8> {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "image".to_string(),
        Json::Arr(img.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    Json::Obj(m).to_string().into_bytes()
}

fn images_body(imgs: &[Vec<f32>]) -> Vec<u8> {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "images".to_string(),
        Json::Arr(
            imgs.iter()
                .map(|img| Json::Arr(img.iter().map(|&v| Json::Num(v as f64)).collect()))
                .collect(),
        ),
    );
    Json::Obj(m).to_string().into_bytes()
}

fn logits_of(j: &Json) -> Vec<f32> {
    j.get("logits")
        .and_then(|l| l.as_arr())
        .expect("response carries logits")
        .iter()
        .map(|v| v.as_f64().expect("logit is a number") as f32)
        .collect()
}

fn synthetic_images(n: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..per).map(|_| rng.normal()).collect())
        .collect()
}

// ---------------------------------------------------------------------------

#[test]
fn infer_parity_with_direct_pool() {
    // The same pool answers over HTTP and in-process; logits must match
    // bit-for-bit (f32 -> JSON f64 shortest-repr -> f32 is lossless).
    let (server, state) = serve(native_pool(1), None, HttpConfig::default());
    let pool = pool_of(&state);
    let per = pool.input_elems_per_image;
    let mut client = client_for(&server);
    for (i, img) in synthetic_images(3, per, 7).into_iter().enumerate() {
        let resp = client
            .post("/v1/infer", &image_body(&img))
            .expect("http infer");
        assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
        let j = resp.json().expect("response is JSON");
        let want = pool.infer(img).expect("direct pool infer");
        assert_eq!(logits_of(&j), want.logits, "image {}: HTTP logits != pool logits", i);
        assert_eq!(
            j.get("predicted_class").and_then(|v| v.as_usize()),
            Some(want.predicted_class),
            "image {}: argmax mismatch",
            i
        );
        // Queue/latency metadata is present and sane.
        assert!(j.get("latency_ms").and_then(|v| v.as_f64()).unwrap_or(-1.0) >= 0.0);
        assert!(j.get("batch_size").and_then(|v| v.as_usize()).unwrap_or(0) >= 1);
        assert!(j.get("queue_depth").and_then(|v| v.as_f64()).is_some());
    }
}

#[test]
fn batch_parity_with_direct_pool() {
    let (server, state) = serve(native_pool(2), None, HttpConfig::default());
    let pool = pool_of(&state);
    let per = pool.input_elems_per_image;
    let imgs = synthetic_images(3, per, 11);
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer_batch", &images_body(&imgs))
        .expect("http infer_batch");
    assert_eq!(resp.status, 200, "body: {:?}", String::from_utf8_lossy(&resp.body));
    let j = resp.json().expect("response is JSON");
    assert_eq!(j.get("count").and_then(|v| v.as_usize()), Some(3));
    let results = j.get("results").and_then(|r| r.as_arr()).expect("results array");
    assert_eq!(results.len(), 3);
    for (i, (r, img)) in results.iter().zip(&imgs).enumerate() {
        let want = pool.infer(img.clone()).expect("direct pool infer");
        assert_eq!(logits_of(r), want.logits, "batch item {} logits mismatch", i);
    }
}

#[test]
fn shed_maps_to_429_with_retry_after() {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(200) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 2 },
    )
    .expect("slow pool start");
    let (server, state) = serve(pool, None, HttpConfig::default());
    let direct = pool_of(&state);
    // Fill both admission slots directly at the pool...
    let a = direct.submit(vec![1.0, 0.0]).expect("slot 1");
    let b = direct.submit(vec![2.0, 0.0]).expect("slot 2");
    // ...then the HTTP request must shed.
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer", &image_body(&[3.0, 0.0]))
        .expect("http exchange");
    assert_eq!(resp.status, 429);
    // Retry-After is computed from the shedding pool's queue depth,
    // replica count and observed latency — not a constant. It must be
    // a positive integer within the clamp, and the JSON body must echo
    // the same value.
    let retry: u64 = resp
        .header("retry-after")
        .expect("429 must carry Retry-After")
        .parse()
        .expect("Retry-After is an integer");
    assert!((1..=60).contains(&retry), "Retry-After {} outside [1, 60]", retry);
    let j = resp.json().expect("shed body is JSON");
    assert_eq!(j.get("queue_capacity").and_then(|v| v.as_usize()), Some(2));
    assert_eq!(
        j.get("retry_after_s").and_then(|v| v.as_usize()),
        Some(retry as usize),
        "body retry_after_s must match the header"
    );
    drop(a);
    drop(b);
}

#[test]
fn request_deadline_maps_to_504() {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(500) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("slow pool start");
    let (server, _state) = serve(pool, Some(Duration::from_millis(30)), HttpConfig::default());
    let mut client = client_for(&server);
    let resp = client
        .post("/v1/infer", &image_body(&[1.0, 0.0]))
        .expect("http exchange");
    assert_eq!(resp.status, 504, "30 ms deadline against a 500 ms backend");
    let batch = client
        .post("/v1/infer_batch", &images_body(&[vec![1.0, 0.0], vec![2.0, 0.0]]))
        .expect("http exchange");
    assert_eq!(batch.status, 504, "batch route honours the deadline too");
}

#[test]
fn malformed_bodies_map_to_400() {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let (server, _state) = serve(pool, None, HttpConfig::default());
    let mut client = client_for(&server);
    for (what, body) in [
        ("unparseable JSON", b"{not json".to_vec()),
        ("missing image field", b"{\"img\":[1,2]}".to_vec()),
        ("non-array image", b"{\"image\":3}".to_vec()),
        ("non-numeric entries", b"{\"image\":[1,\"x\"]}".to_vec()),
        ("wrong length", image_body(&[1.0, 2.0, 3.0])),
        ("empty batch", b"{\"images\":[]}".to_vec()),
    ] {
        let resp = client.post("/v1/infer", &body).expect("http exchange");
        // The batch-shaped probe goes to the batch route.
        let status = if what == "empty batch" {
            client
                .post("/v1/infer_batch", &body)
                .expect("http exchange")
                .status
        } else {
            resp.status
        };
        assert_eq!(status, 400, "{} must map to 400", what);
    }
    // Routing errors.
    assert_eq!(client.get("/nope").expect("404 route").status, 404);
    assert_eq!(client.get("/v1/infer").expect("405 route").status, 405);
}

#[test]
fn oversized_body_maps_to_413() {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let config = HttpConfig { max_body_bytes: 128, ..HttpConfig::default() };
    let (server, _state) = serve(pool, None, config);
    let mut client = client_for(&server);
    let big = image_body(&[0.123456f32; 200]);
    assert!(big.len() > 128);
    let resp = client.post("/v1/infer", &big).expect("http exchange");
    assert_eq!(resp.status, 413, "body over max_body_bytes is rejected before buffering");
    // The connection was closed by the reject; the client transparently
    // reconnects and the edge still serves.
    let ok = client.post("/v1/infer", &image_body(&[1.0, 2.0])).expect("follow-up");
    assert_eq!(ok.status, 200);
}

#[test]
fn chunked_transfer_encoding_maps_to_411() {
    let pool = BackendPool::start(
        |_i| Ok(EchoBackend),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("echo pool start");
    let (server, _state) = serve(pool, None, HttpConfig::default());
    // Raw socket: the HttpClient never sends chunked bodies.
    let mut stream = TcpStream::connect(server.local_addr()).expect("raw connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .expect("raw read timeout");
    stream
        .write_all(
            b"POST /v1/infer HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: chunked\r\n\r\n",
        )
        .expect("raw write");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("raw read");
    assert!(
        response.starts_with("HTTP/1.1 411 "),
        "chunked must be rejected with 411, got: {}",
        response.lines().next().unwrap_or("")
    );
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    let (server, state) = serve(native_pool(1), None, HttpConfig::default());
    let per = pool_of(&state).input_elems_per_image;
    let mut client = client_for(&server);
    let img = synthetic_images(1, per, 3).remove(0);
    for round in 0..3 {
        let health = client.get("/healthz").expect("healthz");
        assert_eq!(health.status, 200, "round {}", round);
        let resp = client.post("/v1/infer", &image_body(&img)).expect("infer");
        assert_eq!(resp.status, 200, "round {}", round);
    }
    // healthz reports the model shape loadgen needs.
    let j = client.get("/healthz").expect("healthz").json().expect("json");
    assert_eq!(j.get("input_elems_per_image").and_then(|v| v.as_usize()), Some(per));
    assert_eq!(j.get("status").and_then(|v| v.as_str()), Some("ok"));
}

/// Pull one labelled-or-not sample value out of a Prometheus exposition.
fn prom_value(text: &str, name_with_labels: &str) -> Option<f64> {
    text.lines()
        .find(|l| l.starts_with(name_with_labels) && !l.starts_with('#'))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

#[test]
fn metrics_scrape_parses_and_counters_advance() {
    let (server, state) = serve(native_pool(2), None, HttpConfig::default());
    let per = pool_of(&state).input_elems_per_image;
    let mut client = client_for(&server);

    let scrape = |client: &mut HttpClient| -> String {
        let resp = client.get("/metrics").expect("metrics scrape");
        assert_eq!(resp.status, 200);
        assert!(
            resp.header("content-type").unwrap_or("").starts_with("text/plain"),
            "Prometheus exposition is text/plain"
        );
        String::from_utf8(resp.body.clone()).expect("exposition is UTF-8")
    };

    let before = scrape(&mut client);
    // Every sample line is `name[{labels}] value` with a finite value.
    let mut samples = 0;
    for line in before.lines().filter(|l| !l.starts_with('#') && !l.trim().is_empty()) {
        let (name, value) = line.rsplit_once(' ').expect("sample line has a value");
        assert!(!name.is_empty());
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("unparseable value: {}", line));
        assert!(v.is_finite(), "non-finite sample: {}", line);
        samples += 1;
    }
    assert!(samples >= 10, "exposition should carry the full gauge set, got {}", samples);

    let infer_before =
        prom_value(&before, "vitfpga_http_route_requests_total{route=\"infer\"}").unwrap_or(0.0);
    let pool_before = prom_value(&before, "vitfpga_pool_requests_total").unwrap_or(0.0);

    let img = synthetic_images(1, per, 5).remove(0);
    for _ in 0..3 {
        assert_eq!(client.post("/v1/infer", &image_body(&img)).expect("infer").status, 200);
    }

    let after = scrape(&mut client);
    let infer_after =
        prom_value(&after, "vitfpga_http_route_requests_total{route=\"infer\"}").expect("counter");
    let pool_after = prom_value(&after, "vitfpga_pool_requests_total").expect("counter");
    assert_eq!(infer_after, infer_before + 3.0, "HTTP route counter must advance");
    assert_eq!(pool_after, pool_before + 3.0, "pool request counter must advance");
    assert!(
        prom_value(&after, "vitfpga_pool_latency_ms_count").unwrap_or(0.0) >= 3.0,
        "latency summary count tracks answered requests"
    );
}

// ---------------------------------------------------------------------------
// model registry over HTTP
// ---------------------------------------------------------------------------

const FAST_SPEC: &str = "test-tiny@b8_rb0.5_rt0.5@seed=5";
const ACCURATE_SPEC: &str = "test-tiny@b8_rb0.7_rt0.9@seed=6";

/// Two differently-pruned synth variants in one registry: "fast"
/// (heavier pruning) and "accurate" (lighter). One intra-layer worker
/// keeps the battery lean; results are thread-count independent.
fn two_variant_registry() -> Registry {
    let defaults = PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 64 };
    Registry::builder(defaults)
        .register("fast", ModelSpec::parse(FAST_SPEC).expect("fast spec"), Some(1))
        .expect("register fast")
        .register("accurate", ModelSpec::parse(ACCURATE_SPEC).expect("accurate spec"), Some(1))
        .expect("register accurate")
        .finish()
        .expect("two-variant registry")
}

/// A dedicated single-model pool built from the same spec a registry
/// entry uses — the bit-exact parity reference.
fn dedicated_pool(spec: &str) -> BackendPool {
    let spec = ModelSpec::parse(spec).expect("parity spec");
    BackendPool::start(
        move |_i| NativeBackend::from_spec(&spec).map(|nb| nb.with_threads(1)),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 64 },
    )
    .expect("dedicated pool start")
}

fn image_body_for(model: &str, img: &[f32]) -> Vec<u8> {
    let mut m = std::collections::BTreeMap::new();
    m.insert("model".to_string(), Json::Str(model.to_string()));
    m.insert(
        "image".to_string(),
        Json::Arr(img.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    Json::Obj(m).to_string().into_bytes()
}

#[test]
fn mixed_models_route_by_name_with_parity_and_labels() {
    // The acceptance bar: one server, two differently-pruned variants;
    // /v1/infer routes by name with bit-exact parity against a
    // dedicated single-model pool for each, and /metrics reports them
    // under distinct model labels.
    let (server, state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let addr = server.local_addr().to_string();
    let fast_ref = dedicated_pool(FAST_SPEC);
    let accurate_ref = dedicated_pool(ACCURATE_SPEC);
    let per = fast_ref.input_elems_per_image;
    assert_eq!(per, accurate_ref.input_elems_per_image);

    // Concurrent clients, each pinned to one variant, interleaving on
    // the wire.
    let handles: Vec<_> = [("fast", 0u64), ("accurate", 1), ("fast", 2), ("accurate", 3)]
        .into_iter()
        .map(|(model, seed)| {
            let addr = addr.clone();
            std::thread::spawn(move || -> Vec<(Vec<f32>, Vec<f32>, usize)> {
                let mut client =
                    HttpClient::connect(&addr, Duration::from_secs(10)).expect("client");
                synthetic_images(3, per, 100 + seed)
                    .into_iter()
                    .map(|img| {
                        let resp = client
                            .post("/v1/infer", &image_body_for(model, &img))
                            .expect("mixed infer");
                        assert_eq!(resp.status, 200, "model {} must answer", model);
                        let j = resp.json().expect("json");
                        assert_eq!(
                            j.get("model").and_then(|v| v.as_str()),
                            Some(model),
                            "response must echo the routed model"
                        );
                        let argmax = j
                            .get("predicted_class")
                            .and_then(|v| v.as_usize())
                            .expect("argmax");
                        (img, logits_of(&j), argmax)
                    })
                    .collect()
            })
        })
        .collect();
    for (w, h) in handles.into_iter().enumerate() {
        let reference = if w % 2 == 0 { &fast_ref } else { &accurate_ref };
        for (i, (img, got, argmax)) in h.join().expect("client thread").into_iter().enumerate()
        {
            let want = reference.infer(img).expect("dedicated pool infer");
            assert_eq!(
                got, want.logits,
                "client {} image {}: HTTP logits != dedicated pool logits",
                w, i
            );
            assert_eq!(argmax, want.predicted_class);
        }
    }
    // The two variants are genuinely different models.
    let probe = synthetic_images(1, per, 999).remove(0);
    let a = fast_ref.infer(probe.clone()).expect("fast ref").logits;
    let b = accurate_ref.infer(probe).expect("accurate ref").logits;
    assert_ne!(a, b, "differently-pruned variants must disagree somewhere");

    // Per-model metric labels, with the right per-model request counts.
    let mut client = client_for(&server);
    let scrape = String::from_utf8(client.get("/metrics").expect("scrape").body)
        .expect("exposition is UTF-8");
    for model in ["fast", "accurate"] {
        let line = format!("vitfpga_pool_requests_total{{model=\"{}\"}}", model);
        let v = prom_value(&scrape, &line)
            .unwrap_or_else(|| panic!("missing {} in scrape:\n{}", line, scrape));
        assert_eq!(v, 6.0, "each variant answered 2 clients x 3 requests");
        assert_eq!(
            prom_value(&scrape, &format!("vitfpga_model_ready{{model=\"{}\"}}", model)),
            Some(1.0),
            "{} must be ready after traffic",
            model
        );
    }
    drop(state);
}

#[test]
fn unknown_model_maps_to_404_and_models_route_lists_variants() {
    let (server, _state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let mut client = client_for(&server);

    // Unknown model: 404 with the registered names in the body.
    let resp = client
        .post("/v1/infer", &image_body_for("nope", &[0.0; 4]))
        .expect("http exchange");
    assert_eq!(resp.status, 404, "unknown model must 404, not 400/503");
    let j = resp.json().expect("404 body is JSON");
    let known: Vec<&str> = j
        .get("models")
        .and_then(|m| m.as_arr())
        .expect("404 lists registered models")
        .iter()
        .filter_map(|v| v.as_str())
        .collect();
    assert_eq!(known, vec!["fast", "accurate"], "registration order preserved");
    // A non-string model field is a 400, not a 404.
    assert_eq!(
        client
            .post("/v1/infer", b"{\"model\": 3, \"image\": [0]}")
            .expect("http exchange")
            .status,
        400
    );

    // /v1/models enumerates both variants with specs and readiness.
    let resp = client.get("/v1/models").expect("models route");
    assert_eq!(resp.status, 200);
    let j = resp.json().expect("models body is JSON");
    assert_eq!(j.get("default").and_then(|v| v.as_str()), Some("fast"));
    let models = j.get("models").and_then(|m| m.as_arr()).expect("models array");
    assert_eq!(models.len(), 2);
    assert_eq!(models[0].get("name").and_then(|v| v.as_str()), Some("fast"));
    assert_eq!(models[0].get("spec").and_then(|v| v.as_str()), Some(FAST_SPEC));
    assert_eq!(models[0].get("default").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(models[1].get("name").and_then(|v| v.as_str()), Some("accurate"));
    assert_eq!(models[1].get("default").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(
        models[1].get("input_elems_per_image").and_then(|v| v.as_usize()),
        Some(32 * 32 * 3),
        "shape known even for cold models"
    );
    // Wrong method on the new route.
    assert_eq!(client.post("/v1/models", b"{}").expect("405").status, 405);
}

#[test]
fn models_build_lazily_on_first_request() {
    let (server, state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let mut client = client_for(&server);

    // Registration alone must not construct pools: healthz says cold,
    // metrics carries ready=0 and no pool samples yet.
    let health = client.get("/healthz").expect("healthz").json().expect("json");
    assert_eq!(
        health.at(&["models", "fast", "status"]).and_then(|v| v.as_str()),
        Some("cold")
    );
    assert_eq!(health.get("status").and_then(|v| v.as_str()), Some("ok"),
               "cold models are healthy, not dead");
    let scrape = String::from_utf8(client.get("/metrics").expect("scrape").body).unwrap();
    assert_eq!(
        prom_value(&scrape, "vitfpga_model_ready{model=\"fast\"}"),
        Some(0.0),
        "scrapes must not cold-start models"
    );
    assert!(!state.registry.is_ready("fast"));

    // First request for one variant builds exactly that variant.
    let img = synthetic_images(1, 32 * 32 * 3, 4).remove(0);
    let resp = client
        .post("/v1/infer", &image_body_for("fast", &img))
        .expect("first fast request");
    assert_eq!(resp.status, 200);
    assert!(state.registry.is_ready("fast"), "first request constructs the pool");
    assert!(!state.registry.is_ready("accurate"), "the other variant stays cold");
    let health = client.get("/healthz").expect("healthz").json().expect("json");
    assert_eq!(
        health.at(&["models", "fast", "status"]).and_then(|v| v.as_str()),
        Some("ok")
    );
    assert_eq!(
        health.at(&["models", "accurate", "status"]).and_then(|v| v.as_str()),
        Some("cold")
    );
}

#[test]
fn loadgen_model_mix_drives_both_models() {
    // The CI registry smoke, in-process: two synth variants served,
    // weighted mixed-model loadgen traffic, both models visible in the
    // scrape afterwards.
    use vitfpga::server::{loadgen, LoadMode, LoadgenConfig};
    let (server, state) = serve_registry(two_variant_registry(), None, HttpConfig::default());
    let cfg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        mode: LoadMode::Closed,
        concurrency: 4,
        requests: 48,
        batch: 1,
        timeout: Duration::from_secs(10),
        seed: 11,
        models: vec![("fast".to_string(), 3.0), ("accurate".to_string(), 1.0)],
    };
    let report = loadgen::run(&cfg).expect("mixed loadgen run");
    assert_eq!(report.sent, 48);
    assert_eq!(report.ok, 48, "no sheds/errors at queue 64: {}", report);
    let per: std::collections::BTreeMap<_, _> = report.per_model.iter().cloned().collect();
    let fast_ok = per.get("fast").copied().unwrap_or(0);
    let accurate_ok = per.get("accurate").copied().unwrap_or(0);
    assert_eq!(fast_ok + accurate_ok, 48, "per-model tallies partition the run");
    assert!(fast_ok > 0 && accurate_ok > 0, "both variants must see traffic");
    assert!(
        fast_ok > accurate_ok,
        "3:1 weights over 48 requests should favour 'fast' ({} vs {})",
        fast_ok,
        accurate_ok
    );

    // Both models answered real inferences, attributed separately.
    let mut client = client_for(&server);
    let scrape =
        String::from_utf8(client.get("/metrics").expect("scrape").body).expect("UTF-8");
    for (model, ok) in [("fast", fast_ok), ("accurate", accurate_ok)] {
        let v = prom_value(
            &scrape,
            &format!("vitfpga_pool_requests_total{{model=\"{}\"}}", model),
        )
        .unwrap_or_else(|| panic!("no labelled counter for {}:\n{}", model, scrape));
        assert_eq!(v, ok as f64, "pool counter for {} matches the client tally", model);
    }
    // Loadgen answered an unknown mix target with a clean error.
    let bad = LoadgenConfig {
        models: vec![("nope".to_string(), 1.0)],
        ..cfg
    };
    let err = loadgen::run(&bad).expect_err("unknown model target must fail fast");
    assert!(
        format!("{:#}", err).contains("nope"),
        "error should name the unknown model: {:#}",
        err
    );
    drop(state);
}

#[test]
fn graceful_shutdown_drains_in_flight_before_socket_closes() {
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(300) }),
        PoolPolicy { replicas: 1, batch: batch_policy(), queue_capacity: 16 },
    )
    .expect("slow pool start");
    let (mut server, _state) = serve(pool, None, HttpConfig::default());
    let addr = server.local_addr();

    // A request that will still be executing when shutdown starts.
    let worker = std::thread::spawn(move || {
        let mut client =
            HttpClient::connect(&addr.to_string(), Duration::from_secs(10)).expect("client");
        client.post("/v1/infer", &image_body(&[5.0, 0.0]))
    });
    // Wait until the server has parsed it (it is now in flight).
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.in_flight() == 0 {
        assert!(Instant::now() < deadline, "request never became in-flight");
        std::thread::sleep(Duration::from_millis(2));
    }

    server.shutdown();

    // The in-flight request was answered, not reset.
    let resp = worker.join().expect("client thread").expect("drained response");
    assert_eq!(resp.status, 200, "in-flight request must complete through the drain");
    let j = resp.json().expect("drained body is JSON");
    assert_eq!(logits_of(&j), vec![5.0, 6.0, 7.0, 8.0]);

    // And only after the drain did the socket close.
    let refused = TcpStream::connect_timeout(&addr, Duration::from_millis(500));
    assert!(refused.is_err(), "listener must be closed after shutdown");
}

#[test]
fn concurrent_keep_alive_clients_all_answered() {
    // The acceptance-bar smoke: N concurrent keep-alive clients, each
    // issuing several requests, all answered correctly by the pool.
    let (server, state) = serve(native_pool(2), None, HttpConfig::default());
    let pool = pool_of(&state);
    let per = pool.input_elems_per_image;
    let addr = server.local_addr().to_string();
    let want = pool
        .infer(synthetic_images(1, per, 21).remove(0))
        .expect("reference infer")
        .logits;

    let handles: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.clone();
            let want = want.clone();
            std::thread::spawn(move || {
                let mut client =
                    HttpClient::connect(&addr, Duration::from_secs(10)).expect("client");
                // Learn the model shape the way loadgen does.
                let health = client.get("/healthz").expect("healthz").json().expect("json");
                let per = health
                    .get("input_elems_per_image")
                    .and_then(|v| v.as_usize())
                    .expect("shape");
                let img = synthetic_images(1, per, 21).remove(0);
                for _ in 0..4 {
                    let resp = client.post("/v1/infer", &image_body(&img)).expect("infer");
                    assert_eq!(resp.status, 200);
                    assert_eq!(logits_of(&resp.json().expect("json")), want);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let m = pool.metrics().expect("pool metrics");
    assert!(m.pool.requests >= 24, "all 6x4 HTTP requests reached the pool");
}
