//! Python-exported structure files through the always-built APIs
//! (Manifest, ModelStructure, AcceleratorSim) — no PJRT needed, so
//! these run on default features whenever trained artifacts exist.
//! They skip (with a message) when no artifacts are present.

use std::path::{Path, PathBuf};

use vitfpga::config::HardwareConfig;
use vitfpga::runtime::Manifest;
use vitfpga::sim::{AcceleratorSim, ModelStructure};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = match std::env::var("VITFPGA_ARTIFACTS") {
        Ok(d) => PathBuf::from(d),
        Err(_) => Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    };
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: no manifest.json under {} (run `make artifacts` and/or set \
             VITFPGA_ARTIFACTS)",
            dir.display()
        );
        None
    }
}

#[test]
fn simulator_consumes_python_structure_files() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let sim = AcceleratorSim::new(HardwareConfig::u250());
    for v in &manifest.variants {
        let st = ModelStructure::load(&dir.join(&v.structure_file)).expect("structure");
        assert_eq!(st.block_size, v.pruning.block_size);
        let r = sim.model_latency(&st, 1);
        assert!(r.total_cycles > 0);
        assert!(r.latency_ms.is_finite());
        // trained/deterministic masks: alpha within 10% of nominal r_b
        for sp in st.sparsity_params() {
            assert!((sp.alpha - st.r_b).abs() < 0.1,
                    "{}: alpha {} vs r_b {}", v.name, sp.alpha, st.r_b);
        }
    }
}

#[test]
fn deit_small_structure_latency_close_to_synthesized() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let Some(v) = manifest.find_matching("deit-small_b16_rb0.5_rt0.5") else { return };
    let st = ModelStructure::load(&dir.join(&v.structure_file)).expect("structure");
    let sim = AcceleratorSim::new(HardwareConfig::u250());
    let from_artifact = sim.model_latency(&st, 1).latency_ms;
    let synth = ModelStructure::synthesize(
        &vitfpga::config::DEIT_SMALL, &v.pruning, 42);
    let from_synth = sim.model_latency(&synth, 1).latency_ms;
    let ratio = from_artifact / from_synth;
    assert!(ratio > 0.8 && ratio < 1.25,
            "artifact {} vs synth {}", from_artifact, from_synth);
}
