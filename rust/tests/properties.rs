//! Cross-module property tests (in-tree `forall` helper; proptest is
//! unavailable offline). These pin the *relationships* between the
//! models: pruning can only reduce cost, the analytic and loop-level
//! cycle models stay ordered, serialization round-trips, and the
//! simulator's latency surface is monotone in both pruning rates.

use vitfpga::complexity::{model_complexity, model_size};
use vitfpga::config::{HardwareConfig, PruningSetting, DEIT_SMALL, TEST_TINY};
use vitfpga::formats::quant;
use vitfpga::sim::{AcceleratorSim, ModelStructure};
use vitfpga::util::json::Json;
use vitfpga::util::prop::forall;
use vitfpga::util::rng::Rng;

fn rand_setting(r: &mut Rng) -> PruningSetting {
    let b = if r.bool(0.5) { 16 } else { 32 };
    let r_b = 0.3 + 0.7 * r.f64();
    let r_t = 0.3 + 0.7 * r.f64();
    PruningSetting::new(b, (r_b * 10.0).round() / 10.0, (r_t * 10.0).round() / 10.0)
}

#[test]
fn latency_monotone_in_rb() {
    let sim = AcceleratorSim::new(HardwareConfig::u250());
    forall(
        1,
        30,
        |r| {
            let s = rand_setting(r);
            let seed = r.next_u64();
            (s, seed)
        },
        |(s, seed)| {
            let mut denser = s.clone();
            denser.r_b = (s.r_b + 0.2).min(1.0);
            let a = sim
                .model_latency(&ModelStructure::synthesize(&DEIT_SMALL, s, *seed), 1)
                .latency_ms;
            let b = sim
                .model_latency(&ModelStructure::synthesize(&DEIT_SMALL, &denser, *seed), 1)
                .latency_ms;
            if a > b * 1.02 {
                return Err(format!("r_b={} gave {} > denser {}", s.r_b, a, b));
            }
            Ok(())
        },
    );
}

#[test]
fn latency_monotone_in_rt() {
    let sim = AcceleratorSim::new(HardwareConfig::u250());
    forall(
        2,
        30,
        |r| (rand_setting(r), r.next_u64()),
        |(s, seed)| {
            let mut keepier = s.clone();
            keepier.r_t = (s.r_t + 0.2).min(1.0);
            let a = sim
                .model_latency(&ModelStructure::synthesize(&DEIT_SMALL, s, *seed), 1)
                .latency_ms;
            let b = sim
                .model_latency(&ModelStructure::synthesize(&DEIT_SMALL, &keepier, *seed), 1)
                .latency_ms;
            if a > b * 1.02 {
                return Err(format!("r_t={} gave {} > keepier {}", s.r_t, a, b));
            }
            Ok(())
        },
    );
}

#[test]
fn pruned_complexity_never_exceeds_dense() {
    forall(
        3,
        100,
        |r| rand_setting(r),
        |s| {
            let dense = model_complexity(&DEIT_SMALL, &PruningSetting::dense(s.block_size), 1, None);
            let pruned = model_complexity(&DEIT_SMALL, s, 1, None);
            // TDM adds small elementwise work; matmul MACs must not grow.
            if pruned.macs() > dense.macs() {
                return Err(format!("{} > {}", pruned.macs(), dense.macs()));
            }
            Ok(())
        },
    );
}

#[test]
fn model_size_monotone_in_rb() {
    forall(
        4,
        100,
        |r| rand_setting(r),
        |s| {
            let mut denser = s.clone();
            denser.r_b = (s.r_b + 0.1).min(1.0);
            let a = model_size(&DEIT_SMALL, s).pruned_params;
            let b = model_size(&DEIT_SMALL, &denser).pruned_params;
            if a > b {
                return Err(format!("params {} > {}", a, b));
            }
            Ok(())
        },
    );
}

#[test]
fn analytic_model_lower_bounds_loop_sim_with_imbalance() {
    // Real (skewed) structures can only be slower than the uniform-phi
    // analytic estimate with load balancing on.
    use vitfpga::sim::perf_model;
    let hw = HardwareConfig::u250();
    let mut bhw = hw;
    bhw.row_streaming = false;
    let sim = vitfpga::sim::Mpca::new(bhw, 16);
    forall(
        5,
        50,
        |r| {
            let heads = r.range(1, 8);
            let cols = r.range(1, 16);
            let rows = r.range(1, 30);
            let pops: Vec<Vec<usize>> = (0..heads)
                .map(|_| (0..cols).map(|_| r.range(0, rows)).collect())
                .collect();
            (pops, rows)
        },
        |(pops, rows)| {
            let heads = pops.len();
            let cols = pops[0].len();
            let total: usize = pops.iter().flat_map(|p| p.iter()).sum();
            let avg_phi = total as f64 / (heads * cols * rows).max(1) as f64;
            let ana = perf_model::sbmm_cycles(
                &bhw, heads, 13 * 16, rows * 16, cols * 16, avg_phi, 16);
            let sim_c = sim.sbmm(13, pops).compute;
            // loop-level >= analytic * 0.99 (analytic ceil can slightly
            // overshoot the per-column exact count on tiny cases)
            if (sim_c as f64) < ana as f64 * 0.5 {
                return Err(format!("sim {} << analytic {}", sim_c, ana));
            }
            Ok(())
        },
    );
}

#[test]
fn json_roundtrip_random_documents() {
    fn rand_json(r: &mut Rng, depth: usize) -> Json {
        // Rng::range is inclusive: scalars only at depth 0.
        match if depth == 0 { r.range(0, 2) } else { r.range(0, 4) } {
            0 => Json::Num((r.range(0, 10_000) as f64) / 8.0),
            1 => Json::Bool(r.bool(0.5)),
            2 => Json::Str(format!("s{}-\"x\"\n", r.range(0, 99))),
            3 => Json::Arr((0..r.range(0, 4)).map(|_| rand_json(r, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..r.range(0, 4) {
                    m.insert(format!("k{}", i), rand_json(r, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    forall(
        6,
        200,
        |r| rand_json(r, 3),
        |j| {
            let text = j.to_string_pretty();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            if back != *j {
                return Err(format!("roundtrip mismatch: {}", text));
            }
            Ok(())
        },
    );
}

#[test]
fn int16_roundtrip_error_bounded() {
    forall(
        7,
        100,
        |r| {
            let n = r.range(1, 2000);
            let scale = 10f32.powi(r.range(0, 6) as i32 - 3);
            (0..n).map(|_| r.normal() * scale).collect::<Vec<f32>>()
        },
        |data| {
            let err = quant::roundtrip_error(data);
            if err.max_rel > 1.0 / 16384.0 {
                return Err(format!("max_rel {}", err.max_rel));
            }
            Ok(())
        },
    );
}

#[test]
fn tokens_per_layer_conserved_across_structures() {
    // synthesize() must agree with PruningSetting::tokens_per_layer.
    forall(
        8,
        50,
        |r| (rand_setting(r), r.next_u64()),
        |(s, seed)| {
            let st = ModelStructure::synthesize(&TEST_TINY, s, *seed);
            let want = s.tokens_per_layer(TEST_TINY.num_tokens(), TEST_TINY.num_layers);
            if st.tokens_per_layer != want {
                return Err(format!("{:?} != {:?}", st.tokens_per_layer, want));
            }
            Ok(())
        },
    );
}

#[test]
fn spmm_matches_dense_reference_over_random_shapes_and_masks() {
    // formats::block_sparse::{spmm, spmm_into} against an independent
    // dense reference: random (possibly ragged) shapes, block sizes,
    // keep rates from ~empty to dense, at least one fully empty block
    // column, and zero-valued x entries (the header walk's skip path).
    use vitfpga::formats::BlockSparseMatrix;
    forall(
        9,
        120,
        |r: &mut Rng| {
            let b = [2usize, 3, 4, 8][r.range(0, 3)];
            let m1 = r.range(1, 5);
            let m2 = r.range(1, 40);
            let n = r.range(1, 40);
            let (rb, cb) = (m2.div_ceil(b), n.div_ceil(b));
            let keep_p = r.f64();
            let mut mask: Vec<bool> = (0..rb * cb).map(|_| r.bool(keep_p)).collect();
            if cb > 1 {
                // Force an empty column of blocks.
                let j = r.below(cb);
                for i in 0..rb {
                    mask[i * cb + j] = false;
                }
            }
            let dense: Vec<f32> = (0..m2 * n).map(|_| r.normal()).collect();
            let x: Vec<f32> = (0..m1 * m2)
                .map(|_| if r.bool(0.2) { 0.0 } else { r.normal() })
                .collect();
            (m1, m2, n, b, mask, dense, x)
        },
        |(m1, m2, n, b, mask, dense, x)| {
            let (m1, m2, n, b) = (*m1, *m2, *n, *b);
            let cb = n.div_ceil(b);
            // Independent reference: zero the pruned blocks on the dense
            // matrix, then a plain triple-loop matmul.
            let mut wm = dense.clone();
            for i in 0..m2 {
                for j in 0..n {
                    if !mask[(i / b) * cb + (j / b)] {
                        wm[i * n + j] = 0.0;
                    }
                }
            }
            let mut want = vec![0.0f32; m1 * n];
            for i in 0..m1 {
                for k in 0..m2 {
                    let xv = x[i * m2 + k];
                    for j in 0..n {
                        want[i * n + j] += xv * wm[k * n + j];
                    }
                }
            }
            let sp = BlockSparseMatrix::from_dense(dense, (m2, n), b, mask, cb);
            let got = sp.spmm(x, m1);
            // spmm_into must fully overwrite a poisoned output buffer.
            let mut also = vec![f32::NAN; m1 * n];
            sp.spmm_into(x, m1, &mut also);
            if got.len() != want.len() {
                return Err(format!("shape: {} vs {}", got.len(), want.len()));
            }
            for (idx, (a, w)) in got.iter().zip(&want).enumerate() {
                if (a - w).abs() > 1e-4 * (1.0 + w.abs()) {
                    return Err(format!("spmm[{}] = {} vs dense {}", idx, a, w));
                }
                let v = also[idx];
                // Bit equality: also catches a NaN poison value left
                // unwritten (NaN would defeat any |a - v| threshold).
                if v.to_bits() != a.to_bits() {
                    return Err(format!("spmm_into[{}] = {} vs spmm {}", idx, v, a));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn batched_fused_forward_matches_serial_per_image() {
    // The token-parallel fused engine vs the serial per-image forward:
    // across random pruning settings (including the TDM growth edge,
    // where r_t near 1 on a tiny token count *grows* the token set),
    // both precisions, random batch sizes and worker counts, every
    // image's logits from the fused batch must stay within 1e-5 of its
    // serial forward. (The kernels are designed bit-exact — they never
    // split a reduction — so 1e-5 is a loose ceiling, not a budget.)
    use vitfpga::backend::{Backend, NativeBackend};
    use vitfpga::funcsim::{FuncSim, Precision};
    forall(
        10,
        10,
        |r: &mut Rng| {
            let setting = if r.bool(0.2) {
                // Growth edge: TDM in every layer, keep rate near 1.
                PruningSetting {
                    block_size: 8,
                    r_b: 1.0,
                    r_t: 0.95,
                    tdm_layers: vec![0, 1, 2, 3],
                }
            } else {
                let mut s = PruningSetting::new(
                    if r.bool(0.5) { 8 } else { 16 },
                    ((0.3 + 0.7 * r.f64()) * 10.0).round() / 10.0,
                    ((0.3 + 0.7 * r.f64()) * 10.0).round() / 10.0,
                );
                // TEST_TINY has 4 layers; re-home the TDMs randomly.
                s.tdm_layers = (0..4).filter(|_| r.bool(0.5)).collect();
                s
            };
            let int16 = r.bool(0.5);
            (setting, int16, r.next_u64(), r.range(2, 5), r.range(1, 4))
        },
        |(setting, int16, seed, batch, threads)| {
            let (batch, threads) = (*batch, *threads);
            let precision = if *int16 { Precision::Int16 } else { Precision::F32 };
            let sim = FuncSim::synthesize(&TEST_TINY, setting, *seed, precision)
                .map_err(|e| e.to_string())?;
            let per = sim.input_elems();
            let classes = sim.num_classes();
            let mut rng = Rng::new(seed ^ 0xF0CA_CC1A);
            let flat: Vec<f32> = (0..batch * per).map(|_| rng.normal()).collect();
            // Serial reference: one image at a time, fresh scratch each.
            let mut want: Vec<f32> = Vec::with_capacity(batch * classes);
            for i in 0..batch {
                want.extend(
                    sim.forward(&flat[i * per..(i + 1) * per])
                        .map_err(|e| e.to_string())?,
                );
            }
            // Fused batch through the datapath directly.
            let mut scratch = sim.batch_scratch(batch);
            let mut got = vec![0.0f32; batch * classes];
            sim.forward_batch_into(&flat, batch, &mut scratch, &mut got, threads)
                .map_err(|e| e.to_string())?;
            for (i, (a, w)) in got.iter().zip(&want).enumerate() {
                if (a - w).abs() > 1e-5 {
                    return Err(format!("logit {}: fused {} vs serial {}", i, a, w));
                }
            }
            // And through the serving backend's fused routing.
            let served = NativeBackend::synthetic(&TEST_TINY, setting, *seed, precision)
                .map_err(|e| e.to_string())?
                .with_threads(threads)
                .with_batch_capacity(batch)
                .infer_batch(&flat, batch)
                .map_err(|e| e.to_string())?;
            for (i, (a, w)) in served.iter().zip(&want).enumerate() {
                if (a - w).abs() > 1e-5 {
                    return Err(format!("logit {}: served {} vs serial {}", i, a, w));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn structure_storage_matches_block_sparse_bytes() {
    // memory model vs the actual packed format: encoder weight bytes from
    // the structure must equal the BlockSparseMatrix storage computed from
    // a matching matrix (headers + payload), for the MSA part.
    use vitfpga::formats::BlockSparseMatrix;
    use vitfpga::sim::memory::encoder_weight_bytes;
    let mut rng = Rng::new(9);
    let s = PruningSetting::new(16, 0.5, 1.0);
    let st = ModelStructure::synthesize(&TEST_TINY, &s, 11);
    let e = &st.encoders[0];
    // Build a matrix with exactly the same per-column populations.
    let dense_mb = e.qkv_col_blocks.len();
    let rows = e.qkv_rows;
    let mut mask = vec![false; rows * dense_mb];
    for (j, &cnt) in e.qkv_col_blocks.iter().enumerate() {
        for i in 0..cnt {
            mask[i * dense_mb + j] = true;
        }
    }
    let w: Vec<f32> = (0..rows * 16 * dense_mb * 16).map(|_| rng.normal()).collect();
    let sp = BlockSparseMatrix::from_dense(&w, (rows * 16, dense_mb * 16), 16, &mask, dense_mb);
    let qkv_blocks: usize = e.qkv_col_blocks.iter().sum();
    let proj_blocks: usize = e.proj_col_blocks.iter().sum();
    let total = encoder_weight_bytes(&st, 0, 2);
    let msa_bytes = sp.storage_bytes(2)
        + proj_blocks * 16 * 16 * 2 + e.proj_col_blocks.len() * 4 + proj_blocks * 4;
    let mlp_bytes = 2 * st.dims.dim * e.neurons_kept * 2;
    assert_eq!(total, msa_bytes + mlp_bytes,
               "memory model disagrees with packed format ({} qkv blocks)", qkv_blocks);
}
