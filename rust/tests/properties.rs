//! Cross-module property tests (in-tree `forall` helper; proptest is
//! unavailable offline). These pin the *relationships* between the
//! models: pruning can only reduce cost, the analytic and loop-level
//! cycle models stay ordered, serialization round-trips, and the
//! simulator's latency surface is monotone in both pruning rates.

use vitfpga::complexity::{model_complexity, model_size};
use vitfpga::config::{HardwareConfig, PruningSetting, DEIT_SMALL, TEST_TINY};
use vitfpga::formats::quant;
use vitfpga::sim::{AcceleratorSim, ModelStructure};
use vitfpga::util::json::Json;
use vitfpga::util::prop::forall;
use vitfpga::util::rng::Rng;

fn rand_setting(r: &mut Rng) -> PruningSetting {
    let b = if r.bool(0.5) { 16 } else { 32 };
    let r_b = 0.3 + 0.7 * r.f64();
    let r_t = 0.3 + 0.7 * r.f64();
    PruningSetting::new(b, (r_b * 10.0).round() / 10.0, (r_t * 10.0).round() / 10.0)
}

#[test]
fn latency_monotone_in_rb() {
    let sim = AcceleratorSim::new(HardwareConfig::u250());
    forall(
        1,
        30,
        |r| {
            let s = rand_setting(r);
            let seed = r.next_u64();
            (s, seed)
        },
        |(s, seed)| {
            let mut denser = s.clone();
            denser.r_b = (s.r_b + 0.2).min(1.0);
            let a = sim
                .model_latency(&ModelStructure::synthesize(&DEIT_SMALL, s, *seed), 1)
                .latency_ms;
            let b = sim
                .model_latency(&ModelStructure::synthesize(&DEIT_SMALL, &denser, *seed), 1)
                .latency_ms;
            if a > b * 1.02 {
                return Err(format!("r_b={} gave {} > denser {}", s.r_b, a, b));
            }
            Ok(())
        },
    );
}

#[test]
fn latency_monotone_in_rt() {
    let sim = AcceleratorSim::new(HardwareConfig::u250());
    forall(
        2,
        30,
        |r| (rand_setting(r), r.next_u64()),
        |(s, seed)| {
            let mut keepier = s.clone();
            keepier.r_t = (s.r_t + 0.2).min(1.0);
            let a = sim
                .model_latency(&ModelStructure::synthesize(&DEIT_SMALL, s, *seed), 1)
                .latency_ms;
            let b = sim
                .model_latency(&ModelStructure::synthesize(&DEIT_SMALL, &keepier, *seed), 1)
                .latency_ms;
            if a > b * 1.02 {
                return Err(format!("r_t={} gave {} > keepier {}", s.r_t, a, b));
            }
            Ok(())
        },
    );
}

#[test]
fn pruned_complexity_never_exceeds_dense() {
    forall(
        3,
        100,
        |r| rand_setting(r),
        |s| {
            let dense = model_complexity(&DEIT_SMALL, &PruningSetting::dense(s.block_size), 1, None);
            let pruned = model_complexity(&DEIT_SMALL, s, 1, None);
            // TDM adds small elementwise work; matmul MACs must not grow.
            if pruned.macs() > dense.macs() {
                return Err(format!("{} > {}", pruned.macs(), dense.macs()));
            }
            Ok(())
        },
    );
}

#[test]
fn model_size_monotone_in_rb() {
    forall(
        4,
        100,
        |r| rand_setting(r),
        |s| {
            let mut denser = s.clone();
            denser.r_b = (s.r_b + 0.1).min(1.0);
            let a = model_size(&DEIT_SMALL, s).pruned_params;
            let b = model_size(&DEIT_SMALL, &denser).pruned_params;
            if a > b {
                return Err(format!("params {} > {}", a, b));
            }
            Ok(())
        },
    );
}

#[test]
fn analytic_model_lower_bounds_loop_sim_with_imbalance() {
    // Real (skewed) structures can only be slower than the uniform-phi
    // analytic estimate with load balancing on.
    use vitfpga::sim::perf_model;
    let hw = HardwareConfig::u250();
    let mut bhw = hw;
    bhw.row_streaming = false;
    let sim = vitfpga::sim::Mpca::new(bhw, 16);
    forall(
        5,
        50,
        |r| {
            let heads = r.range(1, 8);
            let cols = r.range(1, 16);
            let rows = r.range(1, 30);
            let pops: Vec<Vec<usize>> = (0..heads)
                .map(|_| (0..cols).map(|_| r.range(0, rows)).collect())
                .collect();
            (pops, rows)
        },
        |(pops, rows)| {
            let heads = pops.len();
            let cols = pops[0].len();
            let total: usize = pops.iter().flat_map(|p| p.iter()).sum();
            let avg_phi = total as f64 / (heads * cols * rows).max(1) as f64;
            let ana = perf_model::sbmm_cycles(
                &bhw, heads, 13 * 16, rows * 16, cols * 16, avg_phi, 16);
            let sim_c = sim.sbmm(13, pops).compute;
            // loop-level >= analytic * 0.99 (analytic ceil can slightly
            // overshoot the per-column exact count on tiny cases)
            if (sim_c as f64) < ana as f64 * 0.5 {
                return Err(format!("sim {} << analytic {}", sim_c, ana));
            }
            Ok(())
        },
    );
}

#[test]
fn json_roundtrip_random_documents() {
    fn rand_json(r: &mut Rng, depth: usize) -> Json {
        // Rng::range is inclusive: scalars only at depth 0.
        match if depth == 0 { r.range(0, 2) } else { r.range(0, 4) } {
            0 => Json::Num((r.range(0, 10_000) as f64) / 8.0),
            1 => Json::Bool(r.bool(0.5)),
            2 => Json::Str(format!("s{}-\"x\"\n", r.range(0, 99))),
            3 => Json::Arr((0..r.range(0, 4)).map(|_| rand_json(r, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..r.range(0, 4) {
                    m.insert(format!("k{}", i), rand_json(r, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    forall(
        6,
        200,
        |r| rand_json(r, 3),
        |j| {
            let text = j.to_string_pretty();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            if back != *j {
                return Err(format!("roundtrip mismatch: {}", text));
            }
            Ok(())
        },
    );
}

#[test]
fn int16_roundtrip_error_bounded() {
    forall(
        7,
        100,
        |r| {
            let n = r.range(1, 2000);
            let scale = 10f32.powi(r.range(0, 6) as i32 - 3);
            (0..n).map(|_| r.normal() * scale).collect::<Vec<f32>>()
        },
        |data| {
            let err = quant::roundtrip_error(data);
            if err.max_rel > 1.0 / 16384.0 {
                return Err(format!("max_rel {}", err.max_rel));
            }
            Ok(())
        },
    );
}

#[test]
fn tokens_per_layer_conserved_across_structures() {
    // synthesize() must agree with PruningSetting::tokens_per_layer.
    forall(
        8,
        50,
        |r| (rand_setting(r), r.next_u64()),
        |(s, seed)| {
            let st = ModelStructure::synthesize(&TEST_TINY, s, *seed);
            let want = s.tokens_per_layer(TEST_TINY.num_tokens(), TEST_TINY.num_layers);
            if st.tokens_per_layer != want {
                return Err(format!("{:?} != {:?}", st.tokens_per_layer, want));
            }
            Ok(())
        },
    );
}

#[test]
fn spmm_matches_dense_reference_over_random_shapes_and_masks() {
    // formats::block_sparse::{spmm, spmm_into} against an independent
    // dense reference: random (possibly ragged) shapes, block sizes,
    // keep rates from ~empty to dense, at least one fully empty block
    // column, and zero-valued x entries (the header walk's skip path).
    use vitfpga::formats::BlockSparseMatrix;
    forall(
        9,
        120,
        |r: &mut Rng| {
            let b = [2usize, 3, 4, 8][r.range(0, 3)];
            let m1 = r.range(1, 5);
            let m2 = r.range(1, 40);
            let n = r.range(1, 40);
            let (rb, cb) = (m2.div_ceil(b), n.div_ceil(b));
            let keep_p = r.f64();
            let mut mask: Vec<bool> = (0..rb * cb).map(|_| r.bool(keep_p)).collect();
            if cb > 1 {
                // Force an empty column of blocks.
                let j = r.below(cb);
                for i in 0..rb {
                    mask[i * cb + j] = false;
                }
            }
            let dense: Vec<f32> = (0..m2 * n).map(|_| r.normal()).collect();
            let x: Vec<f32> = (0..m1 * m2)
                .map(|_| if r.bool(0.2) { 0.0 } else { r.normal() })
                .collect();
            (m1, m2, n, b, mask, dense, x)
        },
        |(m1, m2, n, b, mask, dense, x)| {
            let (m1, m2, n, b) = (*m1, *m2, *n, *b);
            let cb = n.div_ceil(b);
            // Independent reference: zero the pruned blocks on the dense
            // matrix, then a plain triple-loop matmul.
            let mut wm = dense.clone();
            for i in 0..m2 {
                for j in 0..n {
                    if !mask[(i / b) * cb + (j / b)] {
                        wm[i * n + j] = 0.0;
                    }
                }
            }
            let mut want = vec![0.0f32; m1 * n];
            for i in 0..m1 {
                for k in 0..m2 {
                    let xv = x[i * m2 + k];
                    for j in 0..n {
                        want[i * n + j] += xv * wm[k * n + j];
                    }
                }
            }
            let sp = BlockSparseMatrix::from_dense(dense, (m2, n), b, mask, cb);
            let got = sp.spmm(x, m1);
            // spmm_into must fully overwrite a poisoned output buffer.
            let mut also = vec![f32::NAN; m1 * n];
            sp.spmm_into(x, m1, &mut also);
            if got.len() != want.len() {
                return Err(format!("shape: {} vs {}", got.len(), want.len()));
            }
            for (idx, (a, w)) in got.iter().zip(&want).enumerate() {
                if (a - w).abs() > 1e-4 * (1.0 + w.abs()) {
                    return Err(format!("spmm[{}] = {} vs dense {}", idx, a, w));
                }
                let v = also[idx];
                // Bit equality: also catches a NaN poison value left
                // unwritten (NaN would defeat any |a - v| threshold).
                if v.to_bits() != a.to_bits() {
                    return Err(format!("spmm_into[{}] = {} vs spmm {}", idx, v, a));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn batched_fused_forward_matches_serial_per_image() {
    // The token-parallel fused engine vs the serial per-image forward:
    // across random pruning settings (including the TDM growth edge,
    // where r_t near 1 on a tiny token count *grows* the token set),
    // both precisions, random batch sizes and worker counts, every
    // image's logits from the fused batch must stay within 1e-5 of its
    // serial forward. (The kernels are designed bit-exact — they never
    // split a reduction — so 1e-5 is a loose ceiling, not a budget.)
    use vitfpga::backend::{Backend, NativeBackend};
    use vitfpga::funcsim::{FuncSim, Precision};
    forall(
        10,
        10,
        |r: &mut Rng| {
            let setting = if r.bool(0.2) {
                // Growth edge: TDM in every layer, keep rate near 1.
                PruningSetting {
                    block_size: 8,
                    r_b: 1.0,
                    r_t: 0.95,
                    tdm_layers: vec![0, 1, 2, 3],
                }
            } else {
                let mut s = PruningSetting::new(
                    if r.bool(0.5) { 8 } else { 16 },
                    ((0.3 + 0.7 * r.f64()) * 10.0).round() / 10.0,
                    ((0.3 + 0.7 * r.f64()) * 10.0).round() / 10.0,
                );
                // TEST_TINY has 4 layers; re-home the TDMs randomly.
                s.tdm_layers = (0..4).filter(|_| r.bool(0.5)).collect();
                s
            };
            let int16 = r.bool(0.5);
            (setting, int16, r.next_u64(), r.range(2, 5), r.range(1, 4))
        },
        |(setting, int16, seed, batch, threads)| {
            let (batch, threads) = (*batch, *threads);
            let precision = if *int16 { Precision::Int16 } else { Precision::F32 };
            let sim = FuncSim::synthesize(&TEST_TINY, setting, *seed, precision)
                .map_err(|e| e.to_string())?;
            let per = sim.input_elems();
            let classes = sim.num_classes();
            let mut rng = Rng::new(seed ^ 0xF0CA_CC1A);
            let flat: Vec<f32> = (0..batch * per).map(|_| rng.normal()).collect();
            // Serial reference: one image at a time, fresh scratch each.
            let mut want: Vec<f32> = Vec::with_capacity(batch * classes);
            for i in 0..batch {
                want.extend(
                    sim.forward(&flat[i * per..(i + 1) * per])
                        .map_err(|e| e.to_string())?,
                );
            }
            // Fused batch through the datapath directly.
            let mut scratch = sim.batch_scratch(batch);
            let mut got = vec![0.0f32; batch * classes];
            sim.forward_batch_into(&flat, batch, &mut scratch, &mut got, threads)
                .map_err(|e| e.to_string())?;
            for (i, (a, w)) in got.iter().zip(&want).enumerate() {
                if (a - w).abs() > 1e-5 {
                    return Err(format!("logit {}: fused {} vs serial {}", i, a, w));
                }
            }
            // And through the serving backend's fused routing.
            let served = NativeBackend::synthetic(&TEST_TINY, setting, *seed, precision)
                .map_err(|e| e.to_string())?
                .with_threads(threads)
                .with_batch_capacity(batch)
                .infer_batch(&flat, batch)
                .map_err(|e| e.to_string())?;
            for (i, (a, w)) in served.iter().zip(&want).enumerate() {
                if (a - w).abs() > 1e-5 {
                    return Err(format!("logit {}: served {} vs serial {}", i, a, w));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn csr_panel_layout_bit_exact_vs_boxed_column_walk() {
    // The CSR-of-panels storage must be a pure layout change: the same
    // header walk over the old boxed per-column layout (each block
    // column in its own pair of heap allocations, what `BlockColumn`
    // used to be) yields bit-identical products.
    use vitfpga::formats::BlockSparseMatrix;
    forall(
        11,
        80,
        |r: &mut Rng| {
            let b = [2usize, 4, 8, 16][r.range(0, 3)];
            let m1 = r.range(1, 5);
            let m2 = r.range(1, 48);
            let n = r.range(1, 48);
            let (rb, cb) = (m2.div_ceil(b), n.div_ceil(b));
            let keep_p = r.f64();
            let mask: Vec<bool> = (0..rb * cb).map(|_| r.bool(keep_p)).collect();
            let dense: Vec<f32> = (0..m2 * n).map(|_| r.normal()).collect();
            let x: Vec<f32> = (0..m1 * m2).map(|_| r.normal()).collect();
            (m1, m2, n, b, mask, dense, x)
        },
        |(m1, m2, n, b, mask, dense, x)| {
            let (m1, m2, n, b) = (*m1, *m2, *n, *b);
            let cb = n.div_ceil(b);
            let sp = BlockSparseMatrix::from_dense(dense, (m2, n), b, mask, cb);
            let old: Vec<(Vec<u32>, Vec<f32>)> = (0..sp.col_blocks())
                .map(|j| (sp.col_rows(j).to_vec(), sp.col_values(j).to_vec()))
                .collect();
            let bb = b * b;
            let mut want = vec![0.0f32; m1 * n];
            let mut acc = vec![0.0f32; b];
            for (j, (rows, vals)) in old.iter().enumerate() {
                let c0 = j * b;
                let cw = b.min(n - c0);
                for xr in 0..m1 {
                    let xrow = &x[xr * m2..(xr + 1) * m2];
                    acc[..cw].fill(0.0);
                    for (t, &ib) in rows.iter().enumerate() {
                        let blk = &vals[t * bb..(t + 1) * bb];
                        let r0 = ib as usize * b;
                        let rw = b.min(m2 - r0);
                        for bi in 0..rw {
                            let xv = xrow[r0 + bi];
                            if xv == 0.0 {
                                continue;
                            }
                            for (a, w) in acc[..cw].iter_mut().zip(&blk[bi * b..bi * b + cw]) {
                                *a += xv * w;
                            }
                        }
                    }
                    want[xr * n + c0..xr * n + c0 + cw].copy_from_slice(&acc[..cw]);
                }
            }
            let got = sp.spmm(x, m1);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                if g.to_bits() != w.to_bits() {
                    return Err(format!("[{}] csr {} vs boxed {}", i, g, w));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn integer_spmm_tracks_f32_within_quant_bound() {
    // The true-integer SpMM against the f32 panel walk: the only error
    // sources are the three quantizations (weights, activations,
    // requantized accumulator), each bounded by half a quantization
    // step — a kernel bug (wrong shift, wrong column, dropped block)
    // shows up orders of magnitude above this envelope.
    use vitfpga::formats::quant::quantize_activations;
    use vitfpga::formats::{BlockSparseMatrix, StageRequant};
    use vitfpga::funcsim::kernels::{self, ColumnSchedule};
    forall(
        12,
        60,
        |r: &mut Rng| {
            let b = [4usize, 8, 16][r.range(0, 2)];
            let imgs = r.range(1, 3);
            let rows_per_img = r.range(1, 6);
            let m2 = r.range(4, 40);
            let n = r.range(4, 40);
            let (rb, cb) = (m2.div_ceil(b), n.div_ceil(b));
            let keep_p = 0.3 + 0.7 * r.f64();
            let mask: Vec<bool> = (0..rb * cb).map(|_| r.bool(keep_p)).collect();
            let dense: Vec<f32> = (0..m2 * n).map(|_| r.normal()).collect();
            let x: Vec<f32> = (0..imgs * rows_per_img * m2).map(|_| r.normal()).collect();
            let bias: Option<Vec<f32>> =
                r.bool(0.5).then(|| (0..n).map(|_| r.normal()).collect());
            (imgs, rows_per_img, m2, n, b, mask, dense, x, bias)
        },
        |(imgs, rows_per_img, m2, n, b, mask, dense, x, bias)| {
            let (imgs, rows_per_img, m2, n, b) = (*imgs, *rows_per_img, *m2, *n, *b);
            let cb = n.div_ceil(b);
            let sp = BlockSparseMatrix::from_dense(dense, (m2, n), b, mask, cb);
            let sched = ColumnSchedule::new(&sp);
            let wq = sp.quantize_int16();
            let rows = imgs * rows_per_img;
            let mut want = vec![0.0f32; rows * n];
            kernels::spmm_bias_into(&sp, &sched, x, rows, bias.as_deref(), None, &mut want, 1);
            // Per-image activation quantization, as the datapath does it.
            let mut xq = vec![0i16; rows * m2];
            let mut rq = Vec::with_capacity(imgs);
            for img in 0..imgs {
                let span = img * rows_per_img * m2..(img + 1) * rows_per_img * m2;
                let (q, l2) =
                    quantize_activations(&x[span.clone()], m2, &mut xq[span]);
                rq.push(StageRequant::new(q, wq.quant, l2, wq.max_col_l2));
            }
            let mut got = vec![f32::NAN; rows * n];
            // Uniform row-offset table: rectangular batches are the
            // `offs[i] = i * rows_per_img` special case of the ragged API.
            let offs: Vec<usize> = (0..=imgs).map(|i| i * rows_per_img).collect();
            kernels::spmm_i16_bias_into(
                &sp, &wq, &sched, &xq, rows, &offs, &rq, bias.as_deref(), None,
                &mut got, 2,
            );
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                if !g.is_finite() || (g - w).abs() > 0.1 * (1.0 + w.abs()) {
                    return Err(format!("[{}] int16 {} vs f32 {}", i, g, w));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn int16_forward_tracks_f32_forward() {
    // End-to-end: the integer datapath's logits stay within a
    // characterized envelope of the f32 path across random prunings and
    // synthetic weights (the per-stage quantization error is ~1e-3
    // relative; 0.25 max-norm relative leaves propagation headroom
    // through four layers while still catching any broken stage).
    use vitfpga::funcsim::{FuncSim, Precision};
    forall(
        13,
        8,
        |r: &mut Rng| {
            let mut s = PruningSetting::new(
                if r.bool(0.5) { 8 } else { 16 },
                ((0.4 + 0.6 * r.f64()) * 10.0).round() / 10.0,
                ((0.4 + 0.6 * r.f64()) * 10.0).round() / 10.0,
            );
            s.tdm_layers = (0..4).filter(|_| r.bool(0.5)).collect();
            (s, r.next_u64())
        },
        |(setting, seed)| {
            let f = FuncSim::synthesize(&TEST_TINY, setting, *seed, Precision::F32)
                .map_err(|e| e.to_string())?;
            let q = FuncSim::synthesize(&TEST_TINY, setting, *seed, Precision::Int16)
                .map_err(|e| e.to_string())?;
            let mut rng = Rng::new(seed ^ 0x1616);
            let img: Vec<f32> = (0..f.input_elems()).map(|_| rng.normal()).collect();
            let a = f.forward(&img).map_err(|e| e.to_string())?;
            let b = q.forward(&img).map_err(|e| e.to_string())?;
            let mag = a.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-6);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                if !y.is_finite() || (x - y).abs() / mag > 0.25 {
                    return Err(format!("logit {}: f32 {} vs int16 {} (mag {})", i, x, y, mag));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn adaptive_fused_batch_bit_identical_per_image_to_batch1() {
    // Tentpole invariant: with input-adaptive TDM keep counts the fused
    // ragged batch must still be a pure packing of independent images —
    // each image's logits AND its encoder-exit token count are
    // bit-identical to running that image alone, at any worker count,
    // in both precisions.
    use vitfpga::funcsim::{FuncSim, Precision};
    forall(
        14,
        8,
        |r: &mut Rng| {
            let mut s = PruningSetting::new(
                if r.bool(0.5) { 8 } else { 16 },
                ((0.4 + 0.6 * r.f64()) * 10.0).round() / 10.0,
                ((0.3 + 0.7 * r.f64()) * 10.0).round() / 10.0,
            );
            s.tdm_layers = (0..4).filter(|_| r.bool(0.6)).collect();
            let int16 = r.bool(0.5);
            let threads = if r.bool(0.5) { 1 } else { 3 };
            (s, int16, r.next_u64(), r.range(2, 5), threads)
        },
        |(setting, int16, seed, batch, threads)| {
            let (batch, threads) = (*batch, *threads);
            let precision = if *int16 { Precision::Int16 } else { Precision::F32 };
            let sim = FuncSim::synthesize(&TEST_TINY, setting, *seed, precision)
                .map_err(|e| e.to_string())?
                .with_adaptive_tdm(true);
            let per = sim.input_elems();
            let classes = sim.num_classes();
            let mut rng = Rng::new(seed ^ 0xADA7_71E5);
            let flat: Vec<f32> = (0..batch * per).map(|_| rng.normal()).collect();
            // Batch-1 adaptive reference, one image at a time.
            let mut s1 = sim.batch_scratch(1);
            let mut want = Vec::with_capacity(batch * classes);
            let mut counts = Vec::with_capacity(batch);
            for i in 0..batch {
                let mut out = vec![0.0f32; classes];
                let rows = sim
                    .forward_batch_counted_into(
                        &flat[i * per..(i + 1) * per], 1, &mut s1, &mut out, 1)
                    .map_err(|e| e.to_string())?;
                counts.push(rows);
                want.extend(out);
            }
            // Fused adaptive batch over the ragged row-offset table.
            let mut sn = sim.batch_scratch(batch);
            let mut got = vec![0.0f32; batch * classes];
            let total = sim
                .forward_batch_counted_into(&flat, batch, &mut sn, &mut got, threads)
                .map_err(|e| e.to_string())?;
            if total != counts.iter().sum::<usize>() {
                return Err(format!("total rows {} vs per-image sum {:?}", total, counts));
            }
            let offs = sn.offsets(batch);
            for i in 0..batch {
                if offs[i + 1] - offs[i] != counts[i] {
                    return Err(format!(
                        "image {}: fused exit count {} vs batch-1 {}",
                        i, offs[i + 1] - offs[i], counts[i]));
                }
            }
            for (i, (a, w)) in got.iter().zip(&want).enumerate() {
                if a.to_bits() != w.to_bits() {
                    return Err(format!("logit {}: fused {} vs batch-1 {}", i, a, w));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn schedule_fixed_fused_batch_stays_bit_identical_across_paths() {
    // Regression pin for the ragged-batch refactor: with adaptive mode
    // off, the row-offset table is uniform and the fused batch must be
    // bit-identical to the batch-1 path at every worker count, with
    // every image exiting the encoder at the schedule's fixed count.
    use vitfpga::funcsim::{FuncSim, Precision};
    forall(
        15,
        8,
        |r: &mut Rng| {
            let mut s = PruningSetting::new(
                if r.bool(0.5) { 8 } else { 16 },
                ((0.4 + 0.6 * r.f64()) * 10.0).round() / 10.0,
                ((0.3 + 0.7 * r.f64()) * 10.0).round() / 10.0,
            );
            s.tdm_layers = (0..4).filter(|_| r.bool(0.5)).collect();
            let int16 = r.bool(0.5);
            let threads = if r.bool(0.5) { 1 } else { 3 };
            (s, int16, r.next_u64(), r.range(2, 5), threads)
        },
        |(setting, int16, seed, batch, threads)| {
            let (batch, threads) = (*batch, *threads);
            let precision = if *int16 { Precision::Int16 } else { Precision::F32 };
            let sim = FuncSim::synthesize(&TEST_TINY, setting, *seed, precision)
                .map_err(|e| e.to_string())?;
            let per = sim.input_elems();
            let classes = sim.num_classes();
            let mut rng = Rng::new(seed ^ 0x5C_4ED);
            let flat: Vec<f32> = (0..batch * per).map(|_| rng.normal()).collect();
            // Independent schedule reference: fold the keep rule over the
            // TDM layers.
            let mut n_exit = TEST_TINY.num_tokens();
            for l in 0..TEST_TINY.num_layers {
                if setting.tdm_layers.contains(&l) && setting.r_t < 1.0 {
                    n_exit = setting.tokens_after_tdm(n_exit);
                }
            }
            let mut s1 = sim.batch_scratch(1);
            let mut want = Vec::with_capacity(batch * classes);
            for i in 0..batch {
                let mut out = vec![0.0f32; classes];
                let rows = sim
                    .forward_batch_counted_into(
                        &flat[i * per..(i + 1) * per], 1, &mut s1, &mut out, 1)
                    .map_err(|e| e.to_string())?;
                if rows != n_exit {
                    return Err(format!("batch-1 exit {} vs schedule {}", rows, n_exit));
                }
                want.extend(out);
            }
            let mut sn = sim.batch_scratch(batch);
            let mut got = vec![0.0f32; batch * classes];
            let total = sim
                .forward_batch_counted_into(&flat, batch, &mut sn, &mut got, threads)
                .map_err(|e| e.to_string())?;
            if total != batch * n_exit {
                return Err(format!("fused total {} vs {} x {}", total, batch, n_exit));
            }
            for (i, (a, w)) in got.iter().zip(&want).enumerate() {
                if a.to_bits() != w.to_bits() {
                    return Err(format!("logit {}: fused {} vs batch-1 {}", i, a, w));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn adaptive_counts_vary_per_image_within_schedule_cap() {
    // The point of adaptive mode: two images in one fused batch can exit
    // a TDM layer with different token counts. Across 24 random images
    // the exit counts must not collapse to one value, and no image may
    // exceed the schedule count (the adaptive rule's cap).
    use std::collections::BTreeSet;
    use vitfpga::funcsim::{FuncSim, Precision};
    let mut setting = PruningSetting::new(8, 0.7, 0.7);
    setting.tdm_layers = vec![0, 1, 2, 3];
    let mut cap = TEST_TINY.num_tokens();
    for l in 0..TEST_TINY.num_layers {
        if setting.tdm_layers.contains(&l) {
            cap = setting.tokens_after_tdm(cap);
        }
    }
    let mut distinct = BTreeSet::new();
    for seed in 0..3u64 {
        let sim = FuncSim::synthesize(&TEST_TINY, &setting, 100 + seed, Precision::F32)
            .unwrap()
            .with_adaptive_tdm(true);
        let (per, batch) = (sim.input_elems(), 8);
        let mut rng = Rng::new(0xC00E5 ^ seed);
        let flat: Vec<f32> = (0..batch * per).map(|_| rng.normal()).collect();
        let mut scratch = sim.batch_scratch(batch);
        let mut out = vec![0.0f32; batch * sim.num_classes()];
        sim.forward_batch_counted_into(&flat, batch, &mut scratch, &mut out, 2)
            .unwrap();
        for w in scratch.offsets(batch).windows(2) {
            let n_exit = w[1] - w[0];
            assert!(n_exit <= cap, "adaptive exit {} exceeds schedule {}", n_exit, cap);
            // CLS + at least one kept token + the fused package token.
            assert!(n_exit >= 3, "adaptive exit {} below the 3-token floor", n_exit);
            distinct.insert(n_exit);
        }
    }
    assert!(
        distinct.len() >= 2,
        "adaptive TDM never varied across 24 random images: {:?}",
        distinct
    );
}

#[test]
fn adaptive_mode_edges_match_schedule_fixed() {
    // r_t = 1.0 disables TDM entirely, so adaptive mode must be a
    // bit-exact no-op there; batch 1 is the degenerate ragged table and
    // must still honour the schedule cap with active TDM.
    use vitfpga::funcsim::{FuncSim, Precision};
    let mut setting = PruningSetting::new(8, 0.7, 1.0);
    setting.tdm_layers = vec![0, 1, 2, 3];
    let plain = FuncSim::synthesize(&TEST_TINY, &setting, 5, Precision::F32).unwrap();
    let adaptive = FuncSim::synthesize(&TEST_TINY, &setting, 5, Precision::F32)
        .unwrap()
        .with_adaptive_tdm(true);
    let per = plain.input_elems();
    let classes = plain.num_classes();
    let mut rng = Rng::new(77);
    let flat: Vec<f32> = (0..2 * per).map(|_| rng.normal()).collect();
    let mut sa = plain.batch_scratch(2);
    let mut sb = adaptive.batch_scratch(2);
    let mut a = vec![0.0f32; 2 * classes];
    let mut b = vec![0.0f32; 2 * classes];
    let ra = plain.forward_batch_counted_into(&flat, 2, &mut sa, &mut a, 1).unwrap();
    let rb = adaptive.forward_batch_counted_into(&flat, 2, &mut sb, &mut b, 1).unwrap();
    assert_eq!(ra, rb, "r_t = 1.0 must keep every token in both modes");
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.to_bits(), y.to_bits(), "all-kept edge must be bit-exact");
    }

    let mut s2 = PruningSetting::new(8, 0.7, 0.5);
    s2.tdm_layers = vec![0, 2];
    let sim = FuncSim::synthesize(&TEST_TINY, &s2, 6, Precision::Int16)
        .unwrap()
        .with_adaptive_tdm(true);
    let img: Vec<f32> = (0..sim.input_elems()).map(|_| rng.normal()).collect();
    let mut s1 = sim.batch_scratch(1);
    let mut out = vec![0.0f32; sim.num_classes()];
    let rows = sim
        .forward_batch_counted_into(&img, 1, &mut s1, &mut out, 1)
        .unwrap();
    let mut cap = TEST_TINY.num_tokens();
    for l in 0..TEST_TINY.num_layers {
        if s2.tdm_layers.contains(&l) {
            cap = s2.tokens_after_tdm(cap);
        }
    }
    assert!(
        rows >= 3 && rows <= cap,
        "batch-1 adaptive exit {} outside [3, {}]",
        rows,
        cap
    );
    assert!(out.iter().all(|x| x.is_finite()), "batch-1 adaptive logits finite");
}

#[test]
fn structure_storage_matches_block_sparse_bytes() {
    // memory model vs the actual packed format: encoder weight bytes from
    // the structure must equal the BlockSparseMatrix storage computed from
    // a matching matrix (headers + payload), for the MSA part.
    use vitfpga::formats::BlockSparseMatrix;
    use vitfpga::sim::memory::encoder_weight_bytes;
    let mut rng = Rng::new(9);
    let s = PruningSetting::new(16, 0.5, 1.0);
    let st = ModelStructure::synthesize(&TEST_TINY, &s, 11);
    let e = &st.encoders[0];
    // Build a matrix with exactly the same per-column populations.
    let dense_mb = e.qkv_col_blocks.len();
    let rows = e.qkv_rows;
    let mut mask = vec![false; rows * dense_mb];
    for (j, &cnt) in e.qkv_col_blocks.iter().enumerate() {
        for i in 0..cnt {
            mask[i * dense_mb + j] = true;
        }
    }
    let w: Vec<f32> = (0..rows * 16 * dense_mb * 16).map(|_| rng.normal()).collect();
    let sp = BlockSparseMatrix::from_dense(&w, (rows * 16, dense_mb * 16), 16, &mask, dense_mb);
    let qkv_blocks: usize = e.qkv_col_blocks.iter().sum();
    let proj_blocks: usize = e.proj_col_blocks.iter().sum();
    let total = encoder_weight_bytes(&st, 0, 2);
    let msa_bytes = sp.storage_bytes(2)
        + proj_blocks * 16 * 16 * 2 + e.proj_col_blocks.len() * 4 + proj_blocks * 4;
    let mlp_bytes = 2 * st.dims.dim * e.neurons_kept * 2;
    assert_eq!(total, msa_bytes + mlp_bytes,
               "memory model disagrees with packed format ({} qkv blocks)", qkv_blocks);
}
