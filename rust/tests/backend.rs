//! The artifact-free serving stack: NativeBackend batching vs the serial
//! datapath, and the backend-generic coordinator end-to-end. Runs with
//! the default feature set — no artifacts, no XLA toolchain.

use std::sync::Arc;
use std::time::Duration;

use vitfpga::backend::{Backend, NativeBackend};
use vitfpga::config::{PruningSetting, TEST_TINY};
use vitfpga::coordinator::{BatchPolicy, Coordinator};
use vitfpga::funcsim::{FuncSim, Precision};
use vitfpga::util::rng::Rng;

const SEED: u64 = 42;

fn setting() -> PruningSetting {
    PruningSetting::new(8, 0.7, 0.7)
}

fn backend() -> NativeBackend {
    NativeBackend::synthetic(&TEST_TINY, &setting(), SEED, Precision::F32).unwrap()
}

/// Independent reference model — same (dims, setting, seed) synthesis is
/// bit-deterministic, so this equals the backend's internal model.
fn reference() -> FuncSim {
    FuncSim::synthesize(&TEST_TINY, &setting(), SEED, Precision::F32).unwrap()
}

fn images(n: usize, per: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * per).map(|_| rng.normal()).collect()
}

#[test]
fn infer_batch_matches_serial_forward() {
    // Batches 1 (intra-layer threaded single image), 3 and 8 (fused
    // cross-image batches): the token-parallel engine must be
    // bit-faithful to the serial per-image loop — identical TDHM routing
    // included, since the kernels never split a per-image reduction.
    let mut nb = backend();
    let reference = reference();
    let per = nb.input_elems_per_image();
    let classes = nb.num_classes();
    for (batch, seed) in [(1usize, 10u64), (3, 11), (8, 12)] {
        let flat = images(batch, per, seed);
        let got = nb.infer_batch(&flat, batch).unwrap();
        assert_eq!(got.len(), batch * classes);
        for i in 0..batch {
            let want = reference.forward(&flat[i * per..(i + 1) * per]).unwrap();
            let row = &got[i * classes..(i + 1) * classes];
            let max_err = want
                .iter()
                .zip(row)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err <= 1e-5,
                "batch {} image {}: parallel-vs-serial max err {}",
                batch, i, max_err
            );
            // Stronger than the 1e-5 criterion: the paths are the same
            // code, so the logits are bit-identical.
            assert_eq!(row, want.as_slice(), "batch {} image {}", batch, i);
        }
    }
}

#[test]
fn scratch_reuse_leaks_no_state() {
    // Same image inferred before/after unrelated work in the same arena
    // must give identical logits (the arena fully overwrites or
    // zero-fills every region it reads).
    let sim = reference();
    let per = sim.input_elems();
    let img_a = images(1, per, 21);
    let img_b = images(1, per, 22);
    let mut scratch = sim.scratch();
    let first = sim.forward_with(&img_a, &mut scratch).unwrap();
    let _ = sim.forward_with(&img_b, &mut scratch).unwrap();
    let again = sim.forward_with(&img_a, &mut scratch).unwrap();
    assert_eq!(first, again);
    assert_eq!(first, sim.forward(&img_a).unwrap());
}

#[test]
fn worker_counts_do_not_change_results() {
    let per = backend().input_elems_per_image();
    let flat = images(8, per, 33);
    let mut serial = backend().with_threads(1);
    let want = serial.infer_batch(&flat, 8).unwrap();
    for threads in [2usize, 3, 8, 16] {
        let mut nb = backend().with_threads(threads);
        let got = nb.infer_batch(&flat, 8).unwrap();
        assert_eq!(got, want, "threads={}", threads);
    }
}

#[test]
fn int16_backend_serves_batches() {
    let mut nb =
        NativeBackend::synthetic(&TEST_TINY, &setting(), SEED, Precision::Int16).unwrap();
    let per = nb.input_elems_per_image();
    let flat = images(4, per, 44);
    let got = nb.infer_batch(&flat, 4).unwrap();
    assert_eq!(got.len(), 4 * nb.num_classes());
    assert!(got.iter().all(|x| x.is_finite()));
}

#[test]
fn coordinator_native_serves_concurrent_clients() {
    // submit -> batcher -> native engine -> responder, under concurrent
    // clients, with logits checked against the independent reference.
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(4) };
    let coord = Arc::new(
        Coordinator::start(backend().with_batch_capacity(4), policy).expect("start"),
    );
    assert!(coord.backend_name.starts_with("native:"));
    assert_eq!(coord.num_classes, TEST_TINY.num_classes);
    let reference = Arc::new(reference());
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let coord = Arc::clone(&coord);
        let reference = Arc::clone(&reference);
        handles.push(std::thread::spawn(move || {
            for i in 0..8u64 {
                let mut rng = Rng::new(c * 100 + i);
                let img: Vec<f32> = (0..coord.input_elems_per_image)
                    .map(|_| rng.normal())
                    .collect();
                let resp = coord.infer(img.clone()).expect("infer");
                assert_eq!(resp.logits.len(), coord.num_classes);
                assert!(resp.predicted_class < coord.num_classes);
                assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
                let want = reference.forward(&img).unwrap();
                assert_eq!(resp.logits, want, "client {} request {}", c, i);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics().expect("metrics");
    assert_eq!(m.requests, 32);
    assert!(m.batches <= 32);
    assert!(m.mean_batch_occupancy >= 1.0);
}

#[test]
fn coordinator_native_batches_under_load() {
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) };
    let coord = Arc::new(Coordinator::start(backend(), policy).expect("start"));
    // Fire 16 requests at once; with a 20 ms window the batcher should
    // pack them into fewer than 16 executions.
    let mut rxs = Vec::new();
    for i in 0..16u64 {
        let img = images(1, coord.input_elems_per_image, i);
        rxs.push(coord.submit(img).unwrap());
    }
    for rx in rxs {
        rx.recv().unwrap().expect("response");
    }
    let m = coord.metrics().unwrap();
    assert_eq!(m.requests, 16);
    assert!(m.batches < 16, "no batching happened: {} batches", m.batches);
    assert!(m.mean_batch_occupancy > 1.0);
}

#[test]
fn coordinator_native_rejects_wrong_image_size() {
    let coord = Coordinator::start(backend(), BatchPolicy::default()).expect("start");
    assert!(coord.submit(vec![0.0; 3]).is_err());
}

#[test]
fn coordinator_clamps_policy_to_backend_capacity() {
    let policy = BatchPolicy { max_batch: 1000, max_wait: Duration::from_millis(1) };
    let coord = Coordinator::start(backend().with_batch_capacity(2), policy).expect("start");
    assert_eq!(coord.batch_capacity, 2);
    // Saturating the queue must never produce an over-capacity dispatch
    // (infer_batch would error and the responses would carry it).
    let mut rxs = Vec::new();
    for i in 0..12u64 {
        rxs.push(coord.submit(images(1, coord.input_elems_per_image, i)).unwrap());
    }
    for rx in rxs {
        rx.recv().unwrap().expect("over-capacity dispatch");
    }
}

#[test]
fn backend_loads_artifact_weights_when_present() {
    // Exercise NativeBackend::from_artifacts against a *synthetic*
    // artifacts dir written with the in-tree VITW0001 writer: proves the
    // no-XLA artifact path end-to-end (manifest -> weights -> backend).
    use vitfpga::funcsim::synthesize_tensors;
    use vitfpga::runtime::weights::write_weights;
    use vitfpga::sim::ModelStructure;

    let st = ModelStructure::synthesize(&TEST_TINY, &setting(), 7);
    let ts = synthesize_tensors(&st, 7);
    let dir = std::env::temp_dir().join(format!("vitfpga_backend_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("w.bin"), write_weights(&ts)).unwrap();
    // Minimal structure JSON matching the python exporter's schema.
    let mut enc_json = String::new();
    for (i, e) in st.encoders.iter().enumerate() {
        if i > 0 {
            enc_json.push(',');
        }
        enc_json.push_str(&format!(
            "{{\"qkv_col_blocks\": {:?}, \"qkv_rows\": {}, \
              \"proj_col_blocks\": {:?}, \"proj_rows\": {}, \
              \"neurons_kept\": {}, \"heads_kept\": [true, true]}}",
            e.qkv_col_blocks, e.qkv_rows, e.proj_col_blocks, e.proj_rows, e.neurons_kept
        ));
    }
    std::fs::write(
        dir.join("s.json"),
        format!(
            "{{\"model\": \"test-tiny\", \"block_size\": {}, \"r_b\": {}, \"r_t\": {}, \
              \"tdm_layers\": {:?}, \"tokens_per_layer\": {:?}, \
              \"encoders\": [{}], \
              \"dims\": {{\"num_layers\": 4, \"num_heads\": 2, \"dim\": 32, \
                          \"head_dim\": 16, \"mlp_dim\": 64, \"num_tokens\": 17, \
                          \"patch_dim\": 192, \"num_classes\": 10}}}}",
            st.block_size, st.r_b, st.r_t, st.tdm_layers, st.tokens_per_layer, enc_json
        ),
    )
    .unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        "{\"seed\": 7, \"variants\": [\
          {\"name\": \"test-tiny_b8_rb0.7_rt0.7_bs1\", \"model\": \"test-tiny\", \
           \"batch\": 1, \"use_kernels\": false, \
           \"pruning\": {\"block_size\": 8, \"r_b\": 0.7, \"r_t\": 0.7, \
                         \"tdm_layers\": [2, 6, 9]}, \
           \"files\": {\"hlo\": \"x.hlo.txt\", \"weights\": \"w.bin\", \
                       \"structure\": \"s.json\"}, \
           \"num_weight_tensors\": 56, \
           \"input_shape\": [1, 32, 32, 3], \"num_classes\": 10}]}",
    )
    .unwrap();

    let mut nb = NativeBackend::from_artifacts(&dir, "rb0.7", Precision::F32)
        .expect("from_artifacts");
    assert_eq!(nb.name(), "native:test-tiny_b8_rb0.7_rt0.7_bs1");
    let per = nb.input_elems_per_image();
    let logits = nb.infer_batch(&images(2, per, 5), 2).unwrap();
    assert_eq!(logits.len(), 2 * nb.num_classes());
    assert!(logits.iter().all(|x| x.is_finite()));
    std::fs::remove_dir_all(&dir).ok();
}
