//! Serving battery for the replicated pool: response parity with the
//! single-replica coordinator, bounded-admission shedding, pool-level
//! metrics aggregation, and drop-while-in-flight shutdown behaviour
//! (submitters always get a response or a clean error, never a hang).
//! Runs with the default feature set — no artifacts, no XLA toolchain.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::Result;
use vitfpga::backend::{Backend, NativeBackend};
use vitfpga::config::{PruningSetting, TEST_TINY};
use vitfpga::coordinator::{
    BackendPool, BatchPolicy, Coordinator, InferenceResponse, Overloaded, PoolPolicy,
};
use vitfpga::funcsim::Precision;
use vitfpga::util::rng::Rng;

const SEED: u64 = 42;

fn setting() -> PruningSetting {
    PruningSetting::new(8, 0.7, 0.7)
}

fn native() -> NativeBackend {
    NativeBackend::synthetic(&TEST_TINY, &setting(), SEED, Precision::F32).unwrap()
}

fn native_pool(replicas: usize, batch: BatchPolicy, queue_capacity: usize) -> BackendPool {
    // Same (dims, setting, seed) per replica: synthesis is
    // bit-deterministic, so every replica serves the identical model.
    BackendPool::start(
        |_i| NativeBackend::synthetic(&TEST_TINY, &setting(), SEED, Precision::F32),
        PoolPolicy { replicas, batch, queue_capacity },
    )
    .expect("pool start")
}

fn images(n: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..per).map(|_| rng.normal()).collect())
        .collect()
}

/// Test-only backend that holds every batch for `delay` — makes
/// in-flight windows wide enough to exercise shedding and shutdown
/// deterministically.
struct SlowBackend {
    delay: Duration,
}

impl Backend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }
    fn batch_capacity(&self) -> usize {
        4
    }
    fn num_classes(&self) -> usize {
        3
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        std::thread::sleep(self.delay);
        for i in 0..batch {
            for j in 0..3 {
                out[i * 3 + j] = flat[i * 2] + j as f32;
            }
        }
        Ok(())
    }
}

#[test]
fn pool_response_parity_with_single_coordinator() {
    // Acceptance: an N-replica pool must answer an identical request set
    // with exactly the coordinator's logits — batch composition may
    // differ per replica, but per-image results are batch-independent.
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
    let coord = Coordinator::start(native(), policy).expect("coordinator");
    let pool = native_pool(3, policy, 1024);
    assert_eq!(pool.replicas(), 3);
    assert_eq!(pool.input_elems_per_image, coord.input_elems_per_image);
    assert_eq!(pool.num_classes, coord.num_classes);

    let imgs = images(24, coord.input_elems_per_image, 77);
    let coord_rxs: Vec<_> = imgs
        .iter()
        .map(|img| coord.submit(img.clone()).expect("coord submit"))
        .collect();
    let pool_rxs: Vec<_> = imgs
        .iter()
        .map(|img| pool.submit(img.clone()).expect("pool submit"))
        .collect();
    for (i, (crx, prx)) in coord_rxs.into_iter().zip(pool_rxs).enumerate() {
        let want: InferenceResponse = crx.recv().unwrap().expect("coord response");
        let got: InferenceResponse = prx.recv().unwrap().expect("pool response");
        assert_eq!(got.logits, want.logits, "request {} logits diverge", i);
        assert_eq!(got.predicted_class, want.predicted_class, "request {}", i);
    }

    // Aggregation: the pool report covers exactly the request set, and
    // per-replica reports partition it.
    let m = pool.metrics().expect("pool metrics");
    assert_eq!(m.pool.requests, 24);
    assert_eq!(m.per_replica.len(), 3);
    assert_eq!(m.per_replica.iter().map(|r| r.requests).sum::<usize>(), 24);
    assert!(m.pool.mean_batch_occupancy >= 1.0);
    assert!(m.pool.p50_ms <= m.pool.p99_ms && m.pool.p99_ms <= m.pool.max_ms);
    assert_eq!(coord.metrics().expect("coord metrics").requests, 24);
}

#[test]
fn one_replica_pool_matches_coordinator() {
    // The 1-replica pool is the coordinator special case end-to-end.
    let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) };
    let coord = Coordinator::start(native(), policy).expect("coordinator");
    let pool = native_pool(1, policy, 64);
    for img in images(6, coord.input_elems_per_image, 5) {
        let want = coord.infer(img.clone()).expect("coord infer");
        let got = pool.infer(img).expect("pool infer");
        assert_eq!(got.logits, want.logits);
    }
    let m = pool.metrics().unwrap();
    assert_eq!(m.pool.requests, 6);
    assert_eq!(m.per_replica.len(), 1);
}

#[test]
fn bounded_queue_overflow_returns_overloaded() {
    // Capacity 3, one slow replica holding each batch 100 ms: submits
    // 1-3 are admitted and stay in flight; 4+ must shed with the typed
    // error while the batch executes.
    let pool = BackendPool::start(
        |_i| Ok(SlowBackend { delay: Duration::from_millis(100) }),
        PoolPolicy {
            replicas: 1,
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            queue_capacity: 3,
        },
    )
    .expect("pool start");

    let admitted: Vec<_> = (0..3)
        .map(|i| pool.submit(vec![i as f32, 0.0]).expect("admitted"))
        .collect();
    let err = pool.submit(vec![9.0, 0.0]).expect_err("over capacity");
    let o = err.downcast_ref::<Overloaded>().expect("typed Overloaded");
    assert_eq!(o.capacity, 3);
    assert!(o.queue_depth >= 3);
    assert!(err.to_string().contains("overloaded"), "got: {}", err);
    let stats = pool.stats();
    assert_eq!(stats.shed_count, 1);
    assert_eq!(stats.queue_capacity, 3);

    // Shedding lost nothing that was admitted.
    for (i, rx) in admitted.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("no hang")
            .expect("admitted request answered");
        assert_eq!(resp.logits[0], i as f32);
    }
    // Slots released: the pool admits again.
    assert!(pool.infer(vec![1.0, 0.0]).is_ok());
    assert_eq!(pool.stats().queue_depth, 0);
}

#[test]
fn drop_with_partial_batch_in_flight_errors_cleanly() {
    // max_wait far in the future and a partial final batch: the tail
    // requests are still queued when the pool drops. Their responders
    // must drop (clean error at the receiver), not linger.
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(30) };
    for replicas in [1usize, 2] {
        let pool = native_pool(replicas, policy, 64);
        let per = pool.input_elems_per_image;
        let rxs: Vec<_> = images(6, per, 3)
            .into_iter()
            .map(|img| pool.submit(img).expect("submit"))
            .collect();
        drop(pool);
        let mut answered = 0;
        let mut clean_errors = 0;
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(10)) {
                Ok(Ok(_)) => answered += 1,
                Ok(Err(_)) => clean_errors += 1,
                Err(mpsc::RecvTimeoutError::Disconnected) => clean_errors += 1,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    panic!("submitter hung on dropped pool (replicas={})", replicas)
                }
            }
        }
        assert_eq!(answered + clean_errors, 6, "replicas={}", replicas);
        // With a 30 s wait bound no full batch formed per replica at
        // replicas=2 (3 requests each), so at least the tail errs.
        assert!(clean_errors > 0, "replicas={}: expected dropped tail", replicas);
    }
}

/// Backend whose replica 0 instance panics on its first batch — the
/// worst-case engine death (poisoned thread, unread channel backlog).
struct PanicBackend {
    fail: bool,
}

impl Backend for PanicBackend {
    fn name(&self) -> &str {
        "panicky"
    }
    fn batch_capacity(&self) -> usize {
        4
    }
    fn num_classes(&self) -> usize {
        3
    }
    fn input_elems_per_image(&self) -> usize {
        2
    }
    fn infer_batch_into(&mut self, flat: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        if self.fail {
            panic!("injected backend failure (expected in this test)");
        }
        for (k, o) in out.iter_mut().enumerate().take(batch * 3) {
            *o = flat[(k / 3) * 2] + (k % 3) as f32;
        }
        Ok(())
    }
}

#[test]
fn replica_panic_releases_slots_and_fails_over() {
    let pool = BackendPool::start(
        |i| Ok(PanicBackend { fail: i == 0 }),
        PoolPolicy {
            replicas: 2,
            batch: BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
            queue_capacity: 64,
        },
    )
    .expect("pool start");

    // Sequential traffic: requests routed to replica 0 die with it (a
    // clean error, from the dropped responder or the drained channel);
    // once its receiver is gone, submits fail over to replica 1.
    let (mut answered, mut clean) = (0, 0);
    for round in 0..30 {
        match pool.infer(vec![round as f32, 0.0]) {
            Ok(resp) => {
                assert_eq!(resp.logits[0], round as f32);
                answered += 1;
            }
            Err(_) => clean += 1,
        }
    }
    assert_eq!(answered + clean, 30, "every request resolved");
    assert!(answered > 0, "healthy replica must keep serving after the panic");

    // The panic must not leak admission capacity: received requests are
    // settled by the engine's slot guard, buffered ones by the channel
    // drain, so the depth gauge returns to zero.
    for _ in 0..200 {
        if pool.stats().queue_depth == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(pool.stats().queue_depth, 0, "backend panic leaked admission slots");

    // Metrics survive a dead replica: zero report + dead count instead
    // of a pool-wide error.
    let m = pool.metrics().expect("metrics despite dead replica");
    assert_eq!(m.per_replica.len(), 2);
    assert!(m.dead_replicas <= 1);
    assert_eq!(
        m.pool.requests, answered,
        "surviving replicas' samples cover every answered request"
    );
}

#[test]
fn drop_coordinator_under_concurrent_clients_never_hangs() {
    stress_drop(|| {
        let c = Coordinator::start(
            SlowBackend { delay: Duration::from_millis(3) },
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        )
        .expect("coordinator");
        Arc::new(Submitter::Single(c))
    });
}

#[test]
fn drop_pool_under_concurrent_clients_never_hangs() {
    stress_drop(|| {
        let p = BackendPool::start(
            |_i| Ok(SlowBackend { delay: Duration::from_millis(3) }),
            PoolPolicy {
                replicas: 3,
                batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                queue_capacity: 4096,
            },
        )
        .expect("pool");
        Arc::new(Submitter::Pool(p))
    });
}

enum Submitter {
    Single(Coordinator),
    Pool(BackendPool),
}

impl Submitter {
    fn submit(&self, img: Vec<f32>) -> Result<mpsc::Receiver<Result<InferenceResponse>>> {
        match self {
            Submitter::Single(c) => c.submit(img),
            Submitter::Pool(p) => p.submit(img),
        }
    }
}

/// Concurrent clients submit against a slow server, release their
/// handles, then wait on their receivers while the server (whose last
/// owner is a client thread) is torn down with work still queued and
/// executing. Every receiver must resolve — response or clean error —
/// within the hang guard.
fn stress_drop(make: impl Fn() -> Arc<Submitter>) {
    let server = make();
    let answered = Arc::new(AtomicU64::new(0));
    let clean = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let server = Arc::clone(&server);
        let answered = Arc::clone(&answered);
        let clean = Arc::clone(&clean);
        handles.push(std::thread::spawn(move || {
            let mut rxs = Vec::new();
            for i in 0..25u64 {
                match server.submit(vec![(c * 100 + i) as f32, 0.0]) {
                    Ok(rx) => rxs.push(rx),
                    // Engine already gone: must be an error, not a hang.
                    Err(_) => {
                        clean.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            // Release this client's ownership *before* waiting: the last
            // release tears the server down while receivers from every
            // client are still outstanding.
            drop(server);
            for rx in rxs {
                match rx.recv_timeout(Duration::from_secs(20)) {
                    Ok(Ok(_)) => {
                        answered.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(Err(_)) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                        clean.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        panic!("in-flight request hung across server drop")
                    }
                }
            }
        }));
    }
    drop(server);
    for h in handles {
        h.join().expect("client thread");
    }
    assert_eq!(
        answered.load(Ordering::Relaxed) + clean.load(Ordering::Relaxed),
        100,
        "every submitter saw a response or a clean error"
    );
}
