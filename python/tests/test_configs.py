"""Config invariants: token-count schedule, Table VI settings."""

import math

import pytest

from compile.configs import (DEIT_SMALL, TEST_TINY, PruningConfig,
                             model_by_name, paper_table6_settings)


def test_deit_small_dims():
    cfg = DEIT_SMALL
    assert cfg.num_patches == 196
    assert cfg.num_tokens == 197
    assert cfg.qkv_dim == 384
    assert cfg.patch_dim == 768


def test_tokens_after_tdm_formula():
    pr = PruningConfig(r_t=0.7)
    # 1 CLS + ceil((n-1)*r_t) kept + 1 fused
    assert pr.tokens_after_tdm(197) == 1 + math.ceil(196 * 0.7) + 1


def test_tokens_after_tdm_identity_when_unpruned():
    pr = PruningConfig(r_t=1.0)
    assert pr.tokens_after_tdm(197) == 197


@pytest.mark.parametrize("r_t", [0.5, 0.7, 0.9])
def test_tokens_per_layer_monotone(r_t):
    pr = PruningConfig(r_t=r_t)
    counts = pr.tokens_per_layer(197, 12)
    assert len(counts) == 12
    assert counts[0] == 197
    for a, b in zip(counts, counts[1:]):
        assert b <= a
    # Drops happen exactly after the TDM layers (paper: 3rd/7th/10th).
    for i in range(11):
        if i in pr.tdm_layers:
            assert counts[i + 1] < counts[i]
        else:
            assert counts[i + 1] == counts[i]


def test_paper_table6_settings_count():
    settings = paper_table6_settings()
    assert len(settings) == 14  # 2 baselines + 12 pruned
    assert sum(1 for s in settings if not s.is_pruned) == 2


def test_model_by_name_roundtrip():
    for name in ("deit-small", "deit-tiny", "test-tiny"):
        assert model_by_name(name).name == name
    with pytest.raises(KeyError):
        model_by_name("nope")


def test_tiny_config_block_divisibility():
    # block size must tile the projection dims for clean packing
    assert TEST_TINY.dim % 8 == 0
    assert TEST_TINY.qkv_dim % 8 == 0
