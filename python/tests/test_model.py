"""L2 model tests: dense/pruned consistency, kernel path equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import TEST_TINY, PruningConfig
from compile.model import pruned_vit_logits, vit_forward, vit_logits
from compile.pruning import apply_masks, init_scores, masks_from_scores
from compile.vit.params import (count_params, flatten_params,
                                init_vit_params, param_order,
                                unflatten_params)

CFG = TEST_TINY


@pytest.fixture(scope="module")
def params():
    return init_vit_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))


def test_forward_shapes(params, images):
    z = vit_forward(params, images, CFG)
    assert z.shape == (2, CFG.num_tokens, CFG.dim)
    logits = vit_logits(params, images, CFG)
    assert logits.shape == (2, CFG.num_classes)


def test_unpruned_pruned_model_equals_dense(params, images):
    """r_b = r_t = 1 must reduce exactly to the dense forward."""
    pr = PruningConfig(block_size=8, r_b=1.0, r_t=1.0)
    scores = init_scores(jax.random.PRNGKey(2), CFG, pr)
    masks = masks_from_scores(scores, CFG, pr)
    mp = apply_masks(params, masks)
    dense = vit_logits(params, images, CFG)
    pruned = pruned_vit_logits(mp, images, CFG, pr)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(pruned),
                               rtol=1e-5, atol=1e-5)


def test_kernel_path_matches_jnp_path(params, images):
    pr = PruningConfig(block_size=8, r_b=0.7, r_t=0.7, tdm_layers=(1, 2))
    scores = init_scores(jax.random.PRNGKey(3), CFG, pr)
    mp = apply_masks(params, masks_from_scores(scores, CFG, pr))
    a = pruned_vit_logits(mp, images, CFG, pr, use_kernels=False)
    b = pruned_vit_logits(mp, images, CFG, pr, use_kernels=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_token_pruning_changes_only_after_tdm_layer(params, images):
    """Without weight pruning, prefix layers before the first TDM agree."""
    pr_none = PruningConfig(block_size=8, r_b=1.0, r_t=1.0)
    pr_tok = PruningConfig(block_size=8, r_b=1.0, r_t=0.5, tdm_layers=(2,))
    pr_last = PruningConfig(block_size=8, r_b=1.0, r_t=0.5, tdm_layers=(3,))
    scores = init_scores(jax.random.PRNGKey(4), CFG, pr_none)
    mp = apply_masks(params, masks_from_scores(scores, CFG, pr_none))
    a = pruned_vit_logits(mp, images, CFG, pr_none)
    # TDM in a middle layer changes downstream attention -> logits differ.
    b = pruned_vit_logits(mp, images, CFG, pr_tok)
    assert np.isfinite(np.asarray(b)).all()
    assert not np.allclose(np.asarray(a), np.asarray(b))
    # TDM in the *last* layer cannot change the CLS logits: MLP/LN are
    # per-token and CLS is always retained. A strong structural check.
    c = pruned_vit_logits(mp, images, CFG, pr_last)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               rtol=1e-5, atol=1e-5)


def test_batch_consistency(params):
    """Per-image results must not depend on batch composition."""
    pr = PruningConfig(block_size=8, r_b=0.7, r_t=0.7, tdm_layers=(1,))
    scores = init_scores(jax.random.PRNGKey(5), CFG, pr)
    mp = apply_masks(params, masks_from_scores(scores, CFG, pr))
    imgs = jax.random.normal(jax.random.PRNGKey(6), (4, 32, 32, 3))
    full = pruned_vit_logits(mp, imgs, CFG, pr)
    single = jnp.concatenate(
        [pruned_vit_logits(mp, imgs[i:i + 1], CFG, pr) for i in range(4)])
    np.testing.assert_allclose(np.asarray(full), np.asarray(single),
                               rtol=1e-4, atol=1e-4)


def test_param_flatten_roundtrip(params):
    flat = flatten_params(params, CFG)
    assert len(flat) == len(param_order(CFG))
    back = unflatten_params(flat, CFG)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_param_count_deit_small_matches_paper():
    """Table VI: base DeiT-Small has ~22M parameters."""
    from compile.configs import DEIT_SMALL
    p = init_vit_params(jax.random.PRNGKey(0), DEIT_SMALL)
    n = count_params(p)
    assert 21e6 < n < 23e6, n


def test_pruned_model_weight_zeros_reduce_param_norm(params, images):
    pr = PruningConfig(block_size=8, r_b=0.5, r_t=1.0)
    scores = init_scores(jax.random.PRNGKey(7), CFG, pr)
    mp = apply_masks(params, masks_from_scores(scores, CFG, pr))
    w0 = float(sum(jnp.sum(jnp.abs(p["w_qkv"])) for p in params["encoders"]))
    w1 = float(sum(jnp.sum(jnp.abs(p["w_qkv"])) for p in mp["encoders"]))
    assert w1 < w0 * 0.75
