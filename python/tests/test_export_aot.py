"""Export formats + AOT lowering tests (the Rust interchange contract)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_variant, to_hlo_text, variant_name
from compile.configs import TEST_TINY, PruningConfig
from compile.export import read_weights, write_structure, write_weights
from compile.pruning import init_scores, masks_from_scores, structure_summary
from compile.vit.params import (flatten_params, init_vit_params, param_order)

CFG = TEST_TINY
PR = PruningConfig(block_size=8, r_b=0.7, r_t=0.7, tdm_layers=(1, 2))


def test_weight_roundtrip(tmp_path):
    params = init_vit_params(jax.random.PRNGKey(0), CFG)
    path = str(tmp_path / "w.bin")
    n = write_weights(path, params, CFG)
    loaded = read_weights(path)
    assert len(loaded) == n == len(param_order(CFG))
    flat = flatten_params(params, CFG)
    for (name, data), arr in zip(loaded, flat):
        np.testing.assert_array_equal(data, np.asarray(arr))
    assert loaded[0][0] == "embed/w_embed"


def test_structure_json_schema(tmp_path):
    scores = init_scores(jax.random.PRNGKey(1), CFG, PR)
    masks = masks_from_scores(scores, CFG, PR)
    st = structure_summary(masks, CFG, PR)
    path = str(tmp_path / "s.json")
    write_structure(path, st, CFG, PR)
    doc = json.load(open(path))
    assert doc["block_size"] == 8
    assert len(doc["encoders"]) == CFG.num_layers
    assert len(doc["tokens_per_layer"]) == CFG.num_layers
    assert doc["tokens_per_layer"][0] == CFG.num_tokens
    assert doc["dims"]["dim"] == CFG.dim


def test_variant_name_stable():
    assert (variant_name(CFG, PR, 1, False)
            == "test-tiny_b8_rb0.7_rt0.7_bs1")
    assert variant_name(CFG, PR, 2, True).endswith("_kernels")


def test_lower_variant_hlo_text_structure():
    v = lower_variant(CFG, PR, 1, use_kernels=False)
    hlo = v["hlo"]
    assert hlo.startswith("HloModule")
    assert "ENTRY" in hlo
    # parameter 0 is the image; weights follow
    assert "parameter(0)" in hlo
    assert f"parameter({len(param_order(CFG))})" in hlo
    # output is a tuple of one f32[1, num_classes]
    assert f"f32[1,{CFG.num_classes}]" in hlo


def test_lower_variant_deterministic():
    a = lower_variant(CFG, PR, 1, False)
    b = lower_variant(CFG, PR, 1, False)
    assert a["hlo"] == b["hlo"]
    fa = flatten_params(a["params"], CFG)
    fb = flatten_params(b["params"], CFG)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_lowered_hlo_executes_in_python():
    """Execute the lowered computation with jax and compare to direct call."""
    from compile.pruned_model import pruned_vit_logits
    v = lower_variant(CFG, PR, 1, use_kernels=False)
    flat = flatten_params(v["params"], CFG)
    imgs = jax.random.normal(jax.random.PRNGKey(5), (1, 32, 32, 3))
    direct = pruned_vit_logits(v["params"], imgs, CFG, PR)

    def fn(images, *fl):
        from compile.vit.params import unflatten_params
        p = unflatten_params(list(fl), CFG)
        return (pruned_vit_logits(p, images, CFG, PR),)

    got = jax.jit(fn)(imgs, *flat)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


def test_manifest_contains_required_fields(tmp_path):
    from compile.aot import export_variant
    entry = export_variant(str(tmp_path), CFG, PR, 1, False)
    assert entry["name"] == variant_name(CFG, PR, 1, False)
    for f in entry["files"].values():
        assert os.path.exists(tmp_path / f)
    assert entry["input_shape"] == [1, 32, 32, 3]
    assert entry["pruning"]["r_b"] == 0.7
