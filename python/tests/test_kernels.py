"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes (and block sizes); assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (fuse_tokens, fused_attention, pack_blocks, ref,
                             sbmm, sbmm_from_mask)
from compile.pruning import block_mask_to_element_mask, block_topk_mask


# ---------------------------------------------------------------------------
# SBMM
# ---------------------------------------------------------------------------

@given(
    mb=st.integers(1, 4),     # row blocks of W
    nb=st.integers(1, 4),     # col blocks of W
    m1=st.integers(1, 24),    # rows of X (ragged allowed)
    b=st.sampled_from([2, 4, 8]),
    keep=st.floats(0.2, 1.0),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_sbmm_matches_ref(mb, nb, m1, b, keep, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    m2, d = mb * b, nb * b
    x = jax.random.normal(k1, (m1, m2))
    w = jax.random.normal(k2, (m2, d))
    bm = block_topk_mask(jax.random.normal(k3, (mb, nb)), keep)
    em = block_mask_to_element_mask(bm, (m2, d), b)
    got = sbmm_from_mask(x, w, bm, b)
    want = ref.sbmm_ref(x, w, em)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_sbmm_fully_pruned_column_gives_zero():
    b = 4
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    bm = jnp.asarray([[1.0, 0.0], [1.0, 0.0]])  # column 1 fully pruned
    y = sbmm_from_mask(x, w, bm, b)
    assert np.abs(np.asarray(y[:, b:])).max() == 0.0


def test_pack_blocks_layout():
    """pack_blocks implements the Fig. 5 column-major header layout."""
    b = 2
    w = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    bm = jnp.asarray([[1.0, 0.0], [1.0, 1.0]])
    blocks, header, counts = pack_blocks(w, bm, b)
    assert counts.tolist() == [2, 1]
    assert header[0, :2].tolist() == [0, 1]   # column 0 keeps rows 0,1
    assert header[1, 0].tolist() == 1         # column 1 keeps row 1
    np.testing.assert_allclose(np.asarray(blocks[0, 0]), np.asarray(w[0:2, 0:2]))
    np.testing.assert_allclose(np.asarray(blocks[1, 0]), np.asarray(w[2:4, 2:4]))


def test_sbmm_ragged_input_rows_padded_correctly():
    b = 4
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 8))  # 5 % 4 != 0
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 12))
    bm = jnp.ones((2, 3))
    y = sbmm_from_mask(x, w, bm, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Fused attention + CLS scoring
# ---------------------------------------------------------------------------

@given(
    bsz=st.integers(1, 3), h=st.integers(1, 4),
    n=st.integers(2, 24), d=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=20, deadline=None)
def test_attention_matches_ref(bsz, h, n, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (jax.random.normal(kk, (bsz, h, n, d)) for kk in ks)
    out, cls_attn = fused_attention(q, k, v)
    want_out, want_cls = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cls_attn), np.asarray(want_cls),
                               rtol=1e-4, atol=1e-5)


def test_attention_cls_row_is_stochastic():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (2, 2, 7, 4)) for kk in ks)
    _, cls_attn = fused_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(cls_attn.sum(-1)),
                               np.ones((2, 2)), rtol=1e-5)


def test_attention_softmax_stability_large_logits():
    q = 50.0 * jnp.ones((1, 1, 4, 8))
    k = 50.0 * jnp.ones((1, 1, 4, 8))
    v = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 4, 8))
    out, _ = fused_attention(q, k, v)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# TDM fusion
# ---------------------------------------------------------------------------

@given(bsz=st.integers(1, 4), n=st.integers(1, 32), d=st.sampled_from([4, 8]),
       seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_fuse_tokens_matches_ref(bsz, n, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    tokens = jax.random.normal(k1, (bsz, n, d))
    weights = jax.nn.relu(jax.random.normal(k2, (bsz, n)))
    got = fuse_tokens(tokens, weights)
    want = ref.fuse_ref(tokens, weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_fuse_tokens_zero_weights_safe():
    tokens = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3))
    got = fuse_tokens(tokens, jnp.zeros((2, 5)))
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.zeros((2, 3)), atol=1e-5)


def test_kernels_compose_under_jit():
    """All kernels must lower inside jax.jit (the AOT requirement).

    pack_blocks is deliberately host-side (Section V-A: data layout is an
    *offline* model optimization), so packing happens outside jit and the
    packed arrays are jit arguments.
    """
    ks = jax.random.split(jax.random.PRNGKey(0), 7)
    x = jax.random.normal(ks[0], (4, 8))
    w = jax.random.normal(ks[1], (8, 8))
    bm = jnp.asarray([[1.0, 0.0], [1.0, 1.0]])
    blocks, header, counts = pack_blocks(w, bm, 4)

    def f(x, blocks, header, counts, q, k, v, t, tw):
        y = sbmm(x, blocks, header, counts, 4, 8)
        o, c = fused_attention(q, k, v)
        fz = fuse_tokens(t, tw)
        return y.sum() + o.sum() + c.sum() + fz.sum()

    args = (x, blocks, header, counts,
            jax.random.normal(ks[2], (1, 1, 4, 4)),
            jax.random.normal(ks[3], (1, 1, 4, 4)),
            jax.random.normal(ks[4], (1, 1, 4, 4)),
            jax.random.normal(ks[5], (1, 4, 4)),
            jax.nn.relu(jax.random.normal(ks[6], (1, 4))))
    v1 = f(*args)
    v2 = jax.jit(f)(*args)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-5)
