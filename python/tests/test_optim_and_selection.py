"""AdamW optimizer + parser-safe top-k selection tests."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.optim import adamw_init, adamw_update
from compile.pruned_model import _topk_selection


def test_adamw_first_step_matches_closed_form():
    """With beta corrections, step 1 moves by ~lr * sign(grad) (+ decay)."""
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, -0.5])}
    state = adamw_init(params)
    lr, wd = 0.1, 0.01
    new, _ = adamw_update(grads, state, params, lr, weight_decay=wd)
    # mu_hat = g, nu_hat = g^2 -> update = lr * (sign(g) + wd * p)
    expect = np.asarray([1.0, -2.0]) - lr * (
        np.sign([0.5, -0.5]) + wd * np.asarray([1.0, -2.0]))
    np.testing.assert_allclose(np.asarray(new["w"]), expect, rtol=1e-4)


def test_adamw_converges_on_quadratic():
    params = {"x": jnp.asarray(5.0)}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"x": 2.0 * params["x"]}
        params, state = adamw_update(grads, state, params, 0.05,
                                     weight_decay=0.0)
    assert abs(float(params["x"])) < 0.1


def test_adamw_weight_decay_shrinks_params():
    params = {"x": jnp.asarray(10.0)}
    state = adamw_init(params)
    for _ in range(50):
        grads = {"x": jnp.asarray(0.0)}
        params, state = adamw_update(grads, state, params, 0.1,
                                     weight_decay=0.1)
    assert float(params["x"]) < 10.0


@given(n=st.integers(2, 40), k_frac=st.floats(0.1, 1.0),
       seed=st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_topk_selection_matches_lax_topk(n, k_frac, seed):
    """The parser-safe iterative-argmax selection must equal lax.top_k."""
    k = max(1, int(k_frac * n))
    scores = jax.random.normal(jax.random.PRNGKey(seed), (2, n))
    sel = _topk_selection(scores, k)                  # (2, k, n)
    # each row is one-hot
    np.testing.assert_allclose(np.asarray(sel.sum(-1)), np.ones((2, k)),
                               atol=1e-6)
    got_idx = np.asarray(jnp.argmax(sel, axis=-1))
    _, want_idx = jax.lax.top_k(scores, k)
    np.testing.assert_array_equal(got_idx, np.asarray(want_idx))


def test_topk_selection_is_permutation_matrix_slice():
    scores = jax.random.normal(jax.random.PRNGKey(1), (1, 10))
    sel = _topk_selection(scores, 10)
    # full k -> a permutation matrix
    np.testing.assert_allclose(np.asarray(sel.sum(1)), np.ones((1, 10)),
                               atol=1e-6)
