"""Simultaneous fine-pruning trainer (Algorithm 1) tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import TEST_TINY, PruningConfig
from compile.data import data_stream, make_class_patterns, synth_batch
from compile.pruning.distill import (cross_entropy, distillation_loss,
                                     score_penalty)
from compile.pruning.schedule import cubic_sparsity_schedule
from compile.pruning.train import (TrainState, init_train_state,
                                   make_train_step, masked_params_ste,
                                   train_dense)
from compile.pruning import block
from compile.vit.params import init_vit_params

CFG = TEST_TINY
PR = PruningConfig(block_size=8, r_b=0.6, r_t=0.7, tdm_layers=(1, 2))


def test_cubic_schedule_endpoints():
    assert cubic_sparsity_schedule(0, 100, 0.5) == 1.0
    assert cubic_sparsity_schedule(99, 100, 0.5) == 0.5
    # warmup region dense, cooldown region final
    assert cubic_sparsity_schedule(5, 100, 0.5) == 1.0
    assert cubic_sparsity_schedule(85, 100, 0.5) == 0.5


def test_cubic_schedule_monotone_decreasing():
    vals = [cubic_sparsity_schedule(i, 200, 0.5) for i in range(200)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))
    assert min(vals) == 0.5 and max(vals) == 1.0


def test_distillation_loss_zero_for_identical_logits():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 10))
    assert float(distillation_loss(logits, logits, 4.0)) < 1e-6


def test_distillation_loss_positive_and_temp_scaled():
    t = jax.random.normal(jax.random.PRNGKey(0), (4, 10))
    s = jax.random.normal(jax.random.PRNGKey(1), (4, 10))
    l1 = float(distillation_loss(t, s, 1.0))
    assert l1 > 0


def test_cross_entropy_perfect_prediction():
    logits = jnp.asarray([[100.0, 0.0], [0.0, 100.0]])
    labels = jnp.asarray([0, 1])
    assert float(cross_entropy(logits, labels)) < 1e-5


def test_score_penalty_monotone_in_scores():
    lo = [{"a": -jnp.ones((3, 3))}]
    hi = [{"a": jnp.ones((3, 3))}]
    assert float(score_penalty(hi)) > float(score_penalty(lo))


def test_masked_params_ste_matches_static_topk():
    """The dynamic-threshold trainer mask == exact top-k mask at equal r_b."""
    params = init_vit_params(jax.random.PRNGKey(0), CFG)
    scores = block.init_scores(jax.random.PRNGKey(1), CFG, PR)
    mp_dyn = masked_params_ste(params, scores, jnp.asarray(PR.r_b), CFG, PR)
    masks = block.masks_from_scores(scores, CFG, PR)
    mp_static = block.apply_masks(params, masks)
    for a, b in zip(mp_dyn["encoders"], mp_static["encoders"]):
        got = np.asarray(a["w_qkv"]) != 0
        want = np.asarray(b["w_qkv"]) != 0
        # top-k vs quantile threshold may differ by one block on ties;
        # random normal scores are distinct so they must agree.
        frac = (got == want).mean()
        assert frac > 0.99, frac


def test_synth_batch_shapes_and_labels():
    pats = make_class_patterns(jax.random.PRNGKey(0), CFG)
    imgs, labels = synth_batch(jax.random.PRNGKey(1), pats, CFG, 16)
    assert imgs.shape == (16, 32, 32, 3)
    assert labels.shape == (16,)
    assert int(labels.max()) < CFG.num_classes


def test_synth_batch_deterministic_given_key():
    pats = make_class_patterns(jax.random.PRNGKey(0), CFG)
    a = synth_batch(jax.random.PRNGKey(7), pats, CFG, 4)
    b = synth_batch(jax.random.PRNGKey(7), pats, CFG, 4)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


@pytest.mark.slow
def test_simultaneous_training_reduces_loss():
    pats = make_class_patterns(jax.random.PRNGKey(10), CFG)
    it = data_stream(0, pats, CFG, 32)
    teacher = init_vit_params(jax.random.PRNGKey(0), CFG)
    teacher, _ = train_dense(teacher, CFG, it, 40, lr=1e-3, log_every=1000,
                             log=lambda s: None)
    state = init_train_state(jax.random.PRNGKey(1), CFG, PR,
                             init_params=teacher)
    step_fn = make_train_step(CFG, PR, teacher, lr=5e-4)
    losses = []
    for i in range(30):
        imgs, labels = next(it)
        state, aux = step_fn(state, imgs, labels, jnp.asarray(0.8))
        losses.append(float(aux["loss"]))
    assert losses[-1] < losses[0]


def test_train_step_preserves_pytree_structure():
    pats = make_class_patterns(jax.random.PRNGKey(10), CFG)
    it = data_stream(0, pats, CFG, 8)
    teacher = init_vit_params(jax.random.PRNGKey(0), CFG)
    state = init_train_state(jax.random.PRNGKey(1), CFG, PR)
    step_fn = make_train_step(CFG, PR, teacher)
    imgs, labels = next(it)
    new_state, aux = step_fn(state, imgs, labels, jnp.asarray(0.9))
    assert isinstance(new_state, TrainState)
    assert set(aux) == {"loss", "ce", "distill", "penalty", "acc"}
    assert np.isfinite(float(aux["loss"]))
