"""Static block weight pruning invariants (Section IV-A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import TEST_TINY, PruningConfig
from compile.pruning import (apply_masks, block_mask_to_element_mask,
                             block_topk_mask, head_retained_ratio,
                             init_scores, kept_heads, masks_from_scores,
                             structure_summary)


@given(m=st.integers(1, 12), n=st.integers(1, 12),
       keep=st.floats(0.1, 1.0), seed=st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_block_topk_mask_keeps_exact_count(m, n, keep, seed):
    s = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    mask = block_topk_mask(s, keep)
    k = max(1, int(round(keep * m * n)))
    assert int(mask.sum()) == min(k, m * n)
    # The kept entries are exactly the top-scoring ones.
    flat = np.asarray(s).ravel()
    kept_scores = flat[np.asarray(mask).ravel() > 0]
    dropped = flat[np.asarray(mask).ravel() == 0]
    if dropped.size and kept_scores.size:
        assert kept_scores.min() >= dropped.max()


def test_block_topk_mask_full_keep_is_ones():
    s = jax.random.normal(jax.random.PRNGKey(0), (4, 7))
    assert int(block_topk_mask(s, 1.0).sum()) == 28


@given(m=st.integers(1, 6), n=st.integers(1, 6), b=st.sampled_from([2, 4, 8]))
@settings(max_examples=20, deadline=None)
def test_block_mask_expansion_shape_and_blocks(m, n, b):
    mask = jnp.asarray(
        np.random.RandomState(0).randint(0, 2, (m, n)).astype(np.float32))
    em = block_mask_to_element_mask(mask, (m * b, n * b), b)
    assert em.shape == (m * b, n * b)
    # Every bxb tile is constant and equals the block mask entry.
    em_np = np.asarray(em).reshape(m, b, n, b)
    for i in range(m):
        for j in range(n):
            tile = em_np[i, :, j, :]
            assert (tile == float(mask[i, j])).all()


def test_element_mask_truncation_for_ragged_shapes():
    # grid for (5, 7) at b=2 is ceil(5/2)=3 x ceil(7/2)=4; expansion must
    # truncate the padded remainder back to the element shape.
    mask = jnp.ones((3, 4))
    em = block_mask_to_element_mask(mask, (5, 7), 2)
    assert em.shape == (5, 7)
    assert float(em.min()) == 1.0


def test_apply_masks_zeroes_pruned_weights():
    cfg, pr = TEST_TINY, PruningConfig(block_size=8, r_b=0.5, r_t=1.0)
    params_key, score_key = jax.random.split(jax.random.PRNGKey(0))
    from compile.vit.params import init_vit_params
    params = init_vit_params(params_key, cfg)
    scores = init_scores(score_key, cfg, pr)
    masks = masks_from_scores(scores, cfg, pr)
    mp = apply_masks(params, masks)
    for p, m in zip(mp["encoders"], masks):
        w = np.asarray(p["w_qkv"])
        em = np.asarray(m["w_qkv"])
        assert (w[em == 0] == 0).all()
        # roughly r_b of blocks survive
        frac = float(m["blocks_qkv"].mean())
        assert abs(frac - 0.5) < 0.15
        # MLP neuron coupling: pruned column of W_int <-> pruned row of W_out
        neurons = np.asarray(m["neurons"])
        wi = np.asarray(p["w_int"])
        wo = np.asarray(p["w_out"])
        assert (wi[:, neurons == 0] == 0).all()
        assert (wo[neurons == 0, :] == 0).all()
        assert (np.asarray(p["b_int"])[neurons == 0] == 0).all()


def test_apply_masks_ste_forward_equals_hard_mask():
    cfg, pr = TEST_TINY, PruningConfig(block_size=8, r_b=0.6, r_t=1.0)
    from compile.vit.params import init_vit_params
    params = init_vit_params(jax.random.PRNGKey(0), cfg)
    scores = init_scores(jax.random.PRNGKey(1), cfg, pr)
    masks = masks_from_scores(scores, cfg, pr)
    hard = apply_masks(params, masks, ste=False)
    ste = apply_masks(params, masks, ste=True)
    for a, b in zip(hard["encoders"], ste["encoders"]):
        np.testing.assert_allclose(np.asarray(a["w_qkv"]),
                                   np.asarray(b["w_qkv"]))


def test_kept_heads_all_alive_when_dense():
    cfg, pr = TEST_TINY, PruningConfig(block_size=8, r_b=1.0)
    scores = init_scores(jax.random.PRNGKey(0), cfg, pr)
    masks = masks_from_scores(scores, cfg, pr)
    alive = kept_heads(masks[0]["blocks_qkv"], masks[0]["blocks_proj"], cfg, 8)
    assert bool(jnp.all(alive))
    assert head_retained_ratio(masks, cfg, 8) == 1.0


def test_kept_heads_detects_fully_pruned_head():
    cfg = TEST_TINY
    b = 8
    m_qkv = jnp.ones((cfg.dim // b, 3 * cfg.qkv_dim // b))
    m_proj = jnp.ones((cfg.qkv_dim // b, cfg.dim // b))
    # Kill head 1 everywhere: its q/k/v column ranges and proj row range.
    hd_blocks = cfg.head_dim // b
    for part in range(3):
        c0 = ((part * cfg.num_heads + 1) * cfg.head_dim) // b
        m_qkv = m_qkv.at[:, c0:c0 + hd_blocks].set(0)
    r0 = (1 * cfg.head_dim) // b
    m_proj = m_proj.at[r0:r0 + hd_blocks, :].set(0)
    alive = kept_heads(m_qkv, m_proj, cfg, b)
    assert bool(alive[0]) and not bool(alive[1])


def test_structure_summary_consistency():
    cfg, pr = TEST_TINY, PruningConfig(block_size=8, r_b=0.5)
    scores = init_scores(jax.random.PRNGKey(3), cfg, pr)
    masks = masks_from_scores(scores, cfg, pr)
    summary = structure_summary(masks, cfg, pr)
    assert len(summary) == cfg.num_layers
    for s, m in zip(summary, masks):
        assert sum(s["qkv_col_blocks"]) == int(m["blocks_qkv"].sum())
        assert s["neurons_kept"] == int(m["neurons"].sum())
        assert len(s["heads_kept"]) == cfg.num_heads
        assert all(c <= s["qkv_rows"] for c in s["qkv_col_blocks"])
