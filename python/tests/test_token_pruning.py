"""Dynamic token pruning (TDM) invariants (Section IV-B)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.pruning.token import (tdm, token_drop, token_importance_scores)


def _rand_attn(key, b, h, n):
    """Random row-stochastic attention tensor (B, H, N, N)."""
    logits = jax.random.normal(key, (b, h, n, n))
    return jax.nn.softmax(logits, axis=-1)


def test_scores_shape_and_normalization():
    attn = _rand_attn(jax.random.PRNGKey(0), 2, 3, 9)
    s = token_importance_scores(attn)
    assert s.shape == (2, 8)
    # CLS row of a softmax sums to 1 over all N tokens, so the non-CLS
    # scores sum to <= 1.
    assert float(s.sum(axis=1).max()) <= 1.0 + 1e-5


@given(n=st.integers(4, 32), r_t=st.floats(0.2, 0.95), seed=st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def test_token_drop_output_shape(n, r_t, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    z = jax.random.normal(k1, (2, n, 8))
    scores = jax.nn.softmax(jax.random.normal(k2, (2, n - 1)), axis=-1)
    out, idx = token_drop(z, scores, r_t)
    k = max(1, math.ceil((n - 1) * r_t))
    assert out.shape == (2, 1 + k + 1, 8)
    assert idx.shape == (2, k)


def test_token_drop_preserves_cls():
    z = jax.random.normal(jax.random.PRNGKey(0), (3, 10, 4))
    scores = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (3, 9)))
    out, _ = token_drop(z, scores, 0.5)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(z[:, 0]))


def test_token_drop_keeps_top_scored_tokens_in_order():
    z = jnp.arange(1 * 6 * 2, dtype=jnp.float32).reshape(1, 6, 2)
    scores = jnp.asarray([[0.1, 0.5, 0.05, 0.3, 0.05]])
    out, idx = token_drop(z, scores, 0.4)  # k = ceil(5*0.4) = 2
    assert idx.tolist() == [[1, 3]]  # descending score order
    np.testing.assert_allclose(np.asarray(out[0, 1]), np.asarray(z[0, 2]))
    np.testing.assert_allclose(np.asarray(out[0, 2]), np.asarray(z[0, 4]))


def test_fused_token_is_weighted_average_of_dropped():
    z = jax.random.normal(jax.random.PRNGKey(2), (1, 6, 3))
    scores = jnp.asarray([[0.4, 0.3, 0.1, 0.15, 0.05]])
    out, idx = token_drop(z, scores, 0.4)  # keeps tokens 0,1 -> drops 2,3,4
    dropped = np.asarray(z[0, 3:6])        # token i maps to z[:, i+1]
    w = np.asarray(scores[0, 2:5])
    expected = (w[:, None] * dropped).sum(0) / (w.sum() + 1e-6)
    np.testing.assert_allclose(np.asarray(out[0, -1]), expected, rtol=1e-5)


def test_token_drop_permutation_consistency():
    """Permuting non-CLS tokens permutes which are kept, not their values."""
    key = jax.random.PRNGKey(3)
    z = jax.random.normal(key, (1, 8, 4))
    scores = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(4), (1, 7)))
    out1, _ = token_drop(z, scores, 0.5)
    perm = np.asarray([3, 1, 0, 2, 6, 5, 4])
    z2 = jnp.concatenate([z[:, :1], z[:, 1:][:, perm]], axis=1)
    s2 = scores[:, perm]
    out2, _ = token_drop(z2, s2, 0.5)
    # Same multiset of kept tokens (sorted by score, so same order).
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5)


def test_tdm_wrapper_matches_token_drop():
    attn = _rand_attn(jax.random.PRNGKey(5), 2, 3, 9)
    z = jax.random.normal(jax.random.PRNGKey(6), (2, 9, 4))
    out = tdm(z, attn, 0.6)
    s = token_importance_scores(attn)
    expected, _ = token_drop(z, s, 0.6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected))


def test_tdm_reduces_computation_tokens():
    attn = _rand_attn(jax.random.PRNGKey(7), 1, 2, 33)
    z = jax.random.normal(jax.random.PRNGKey(8), (1, 33, 4))
    out = tdm(z, attn, 0.5)
    assert out.shape[1] == 1 + math.ceil(32 * 0.5) + 1 == 18
