"""Model and pruning configurations.

Mirrors Section VI of the paper: the evaluated model is DeiT-Small
(12 encoders, H=6 heads, D=384, D_mlp=1536, 224x224 images with 16x16
patches -> N=197 tokens including CLS). Pruning settings sweep the block
size b over {16, 32}, the weight top-k rate r_b over {0.5, 0.7} and the
token keep rate r_t over {0.5, 0.7, 0.9}; the Token Dropping Module (TDM)
is inserted in the 3rd, 7th and 10th encoders (1-indexed).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    """Structural hyper-parameters of a ViT/DeiT classifier."""

    name: str = "deit-small"
    image_size: int = 224
    patch_size: int = 16
    in_channels: int = 3
    num_layers: int = 12
    num_heads: int = 6
    dim: int = 384           # D: token embedding dimension
    head_dim: int = 64       # D': per-head hidden dimension
    mlp_dim: int = 1536      # D_mlp
    num_classes: int = 1000

    @property
    def num_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def num_tokens(self) -> int:
        """N: patches + the CLS token."""
        return self.num_patches + 1

    @property
    def patch_dim(self) -> int:
        """Flattened patch vector length P^2 * C."""
        return self.patch_size * self.patch_size * self.in_channels

    @property
    def qkv_dim(self) -> int:
        """H * D' (the concatenated per-head hidden dimension)."""
        return self.num_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class PruningConfig:
    """Pruning hyper-parameters (Section IV / Section VI).

    r_b:  weight-pruning top-k rate (fraction of blocks *kept*).
    r_t:  token keep rate; at each TDM, ceil((N-1) * r_t) attentive tokens
          are retained, the rest are fused into one token.
    b:    square block size for block-wise weight pruning.
    tdm_layers: 0-indexed encoder indices hosting a TDM. The paper inserts
          TDM in the 3rd, 7th and 10th encoder layers -> (2, 6, 9).
    """

    block_size: int = 16
    r_b: float = 1.0
    r_t: float = 1.0
    tdm_layers: Tuple[int, ...] = (2, 6, 9)
    # Simultaneous-pruning training hyper-parameters (Section VI).
    lambda_score: float = 1e-4     # lambda for the ||sigma(S)|| penalty (Eq. 8)
    lambda_distill: float = 0.5    # weight of the distillation loss
    lambda_normal: float = 0.5     # weight of the generic loss
    distill_temperature: float = 4.0

    @property
    def is_pruned(self) -> bool:
        return self.r_b < 1.0 or self.r_t < 1.0

    def tokens_after_tdm(self, n: int) -> int:
        """Token count after one TDM given n input tokens (incl. CLS).

        ceil((n-1) * r_t) attentive tokens + 1 fused token + CLS.
        """
        if self.r_t >= 1.0:
            return n
        return 1 + math.ceil((n - 1) * self.r_t) + 1

    def tokens_per_layer(self, n0: int, num_layers: int) -> Tuple[int, ...]:
        """Number of *input* tokens for each encoder layer."""
        counts = []
        n = n0
        for layer in range(num_layers):
            counts.append(n)
            if layer in self.tdm_layers:
                n = self.tokens_after_tdm(n)
        return tuple(counts)


# ---------------------------------------------------------------------------
# Named configurations
# ---------------------------------------------------------------------------

DEIT_SMALL = ViTConfig()

DEIT_TINY = ViTConfig(
    name="deit-tiny",
    num_heads=3,
    dim=192,
    head_dim=64,
    mlp_dim=768,
)

# Scaled-down config used for fast unit tests and the synthetic-data
# training proxy (see DESIGN.md Substitutions). Structure is identical
# (CLS token, multi-head MSA, TDM insertion points, block pruning).
TEST_TINY = ViTConfig(
    name="test-tiny",
    image_size=32,
    patch_size=8,
    in_channels=3,
    num_layers=4,
    num_heads=2,
    dim=32,
    head_dim=16,
    mlp_dim=64,
    num_classes=10,
)

TEST_TINY_PRUNING = PruningConfig(block_size=8, r_b=0.7, r_t=0.7, tdm_layers=(1, 2))


def model_by_name(name: str) -> ViTConfig:
    table = {
        "deit-small": DEIT_SMALL,
        "deit-tiny": DEIT_TINY,
        "test-tiny": TEST_TINY,
    }
    if name not in table:
        raise KeyError(f"unknown model config '{name}' (have {sorted(table)})")
    return table[name]


def paper_table6_settings() -> Tuple[PruningConfig, ...]:
    """The 14 pruning settings of Table VI (2 baselines + 12 pruned)."""
    settings = []
    for b in (16, 32):
        settings.append(PruningConfig(block_size=b, r_b=1.0, r_t=1.0))
    for b in (16, 32):
        for r_b in (0.5, 0.7):
            for r_t in (0.5, 0.7, 0.9):
                settings.append(PruningConfig(block_size=b, r_b=r_b, r_t=r_t))
    return tuple(settings)
