"""Parameter initialization and deterministic flattening order.

The flattening order defined by :func:`param_order` is the contract between
the AOT pipeline (aot.py / export.py) and the Rust runtime: HLO artifacts
take weights as positional parameters in exactly this order, and
``weights_*.bin`` stores tensors in the same order.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.configs import ViTConfig


def _trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def init_encoder_params(key, cfg: ViTConfig) -> Dict[str, jnp.ndarray]:
    d, hd, nh, dm = cfg.dim, cfg.head_dim, cfg.num_heads, cfg.mlp_dim
    ks = jax.random.split(key, 4)
    return {
        "ln1_g": jnp.ones((d,)),
        "ln1_b": jnp.zeros((d,)),
        "w_qkv": _trunc_normal(ks[0], (d, 3 * nh * hd)),
        "b_qkv": jnp.zeros((3 * nh * hd,)),
        "w_proj": _trunc_normal(ks[1], (nh * hd, d)),
        "b_proj": jnp.zeros((d,)),
        "ln2_g": jnp.ones((d,)),
        "ln2_b": jnp.zeros((d,)),
        "w_int": _trunc_normal(ks[2], (d, dm)),
        "b_int": jnp.zeros((dm,)),
        "w_out": _trunc_normal(ks[3], (dm, d)),
        "b_out": jnp.zeros((d,)),
    }


def init_vit_params(key, cfg: ViTConfig) -> Dict:
    keys = jax.random.split(key, cfg.num_layers + 3)
    params = {
        "embed": {
            "w_embed": _trunc_normal(keys[0], (cfg.patch_dim, cfg.dim)),
            "b_embed": jnp.zeros((cfg.dim,)),
            "cls": _trunc_normal(keys[1], (1, 1, cfg.dim)),
            "pos": _trunc_normal(keys[2], (1, cfg.num_tokens, cfg.dim)),
        },
        "encoders": [init_encoder_params(keys[3 + i], cfg)
                     for i in range(cfg.num_layers)],
        "head": {
            "ln_g": jnp.ones((cfg.dim,)),
            "ln_b": jnp.zeros((cfg.dim,)),
            "w_head": _trunc_normal(keys[-1], (cfg.dim, cfg.num_classes)),
            "b_head": jnp.zeros((cfg.num_classes,)),
        },
    }
    return params


ENCODER_KEYS = ("ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_proj", "b_proj",
                "ln2_g", "ln2_b", "w_int", "b_int", "w_out", "b_out")
EMBED_KEYS = ("w_embed", "b_embed", "cls", "pos")
HEAD_KEYS = ("ln_g", "ln_b", "w_head", "b_head")


def param_order(cfg: ViTConfig) -> List[Tuple[str, ...]]:
    """Deterministic (path...) list: embed, encoders[0..L-1], head."""
    order: List[Tuple[str, ...]] = [("embed", k) for k in EMBED_KEYS]
    for i in range(cfg.num_layers):
        order.extend(("encoders", str(i), k) for k in ENCODER_KEYS)
    order.extend(("head", k) for k in HEAD_KEYS)
    return order


def flatten_params(params: Dict, cfg: ViTConfig) -> List[jnp.ndarray]:
    out = []
    for path in param_order(cfg):
        node = params
        for p in path:
            node = node[int(p)] if isinstance(node, list) else node[p]
        out.append(node)
    return out


def unflatten_params(flat: List[jnp.ndarray], cfg: ViTConfig) -> Dict:
    it = iter(flat)
    params = {
        "embed": {k: next(it) for k in EMBED_KEYS},
        "encoders": [{k: next(it) for k in ENCODER_KEYS}
                     for _ in range(cfg.num_layers)],
        "head": {k: next(it) for k in HEAD_KEYS},
    }
    return params


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
