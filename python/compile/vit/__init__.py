"""Pure-JAX ViT/DeiT model family (L2 substrate)."""

from compile.vit.params import init_vit_params, count_params, param_order  # noqa: F401
from compile.vit.model import vit_forward, vit_logits  # noqa: F401
