"""ViT building blocks as pure functions over parameter dicts.

Equations follow Section II-A of the paper:
  MSA:  [Q,K,V] = Z U_qkv;  A = softmax(QK^T / sqrt(D'));  SA = AV
        MSA(Z) = [SA_1 ... SA_H] W_proj                  (Eqs. 2-5)
  Encoder: Z' = MSA(LN(Z)) + Z;  Z_out = MLP(LN(Z')) + Z' (Eqs. 1, 6)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def layer_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    # tanh approximation, matching the EM module's polynomial evaluation.
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x ** 3)))


def attention_scores(q: jnp.ndarray, k: jnp.ndarray, head_dim: int) -> jnp.ndarray:
    """softmax(QK^T / sqrt(D')) per head. q,k: (..., H, N, D')."""
    logits = jnp.einsum("...hnd,...hmd->...hnm", q, k) / jnp.sqrt(
        jnp.asarray(head_dim, q.dtype))
    return jax.nn.softmax(logits, axis=-1)


def msa(z: jnp.ndarray, p: dict, num_heads: int, head_dim: int,
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-head self-attention.

    z: (B, N, D).  Returns (out (B, N, D), attn (B, H, N, N)); the attention
    matrix is surfaced so a TDM can derive token importance scores from it.
    """
    b, n, _ = z.shape
    qkv = z @ p["w_qkv"] + p["b_qkv"]                       # (B, N, 3*H*D')
    qkv = qkv.reshape(b, n, 3, num_heads, head_dim)
    q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))  # (B,H,N,D')
    attn = attention_scores(q, k, head_dim)                  # (B, H, N, N)
    sa = jnp.einsum("bhnm,bhmd->bhnd", attn, v)              # (B, H, N, D')
    sa = sa.transpose(0, 2, 1, 3).reshape(b, n, num_heads * head_dim)
    out = sa @ p["w_proj"] + p["b_proj"]
    return out, attn


def mlp(z: jnp.ndarray, p: dict) -> jnp.ndarray:
    h = gelu(z @ p["w_int"] + p["b_int"])
    return h @ p["w_out"] + p["b_out"]


def patch_embed(images: jnp.ndarray, p: dict, patch_size: int) -> jnp.ndarray:
    """Patchify (B, H, W, C) images and linearly embed each patch.

    Returns (B, num_patches, D).
    """
    b, h, w, c = images.shape
    ph = h // patch_size
    pw = w // patch_size
    x = images.reshape(b, ph, patch_size, pw, patch_size, c)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, ph * pw, patch_size * patch_size * c)
    return x @ p["w_embed"] + p["b_embed"]


def encoder(z: jnp.ndarray, p: dict, num_heads: int, head_dim: int,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One transformer encoder. Returns (Z_out, attn)."""
    zn = layer_norm(z, p["ln1_g"], p["ln1_b"])
    att_out, attn = msa(zn, p, num_heads, head_dim)
    z_prime = att_out + z                                    # Eq. 1
    zn2 = layer_norm(z_prime, p["ln2_g"], p["ln2_b"])
    z_out = mlp(zn2, p) + z_prime                            # Eq. 6
    return z_out, attn
