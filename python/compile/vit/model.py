"""Dense (unpruned) ViT forward — the baseline and distillation teacher."""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from compile.configs import ViTConfig
from compile.vit import layers


def vit_forward(params: Dict, images: jnp.ndarray, cfg: ViTConfig,
                ) -> jnp.ndarray:
    """images: (B, H, W, C) -> final token matrix (B, N, D)."""
    z = layers.patch_embed(images, params["embed"], cfg.patch_size)
    cls = jnp.broadcast_to(params["embed"]["cls"],
                           (z.shape[0], 1, cfg.dim)).astype(z.dtype)
    z = jnp.concatenate([cls, z], axis=1) + params["embed"]["pos"]
    for p in params["encoders"]:
        z, _ = layers.encoder(z, p, cfg.num_heads, cfg.head_dim)
    return z


def vit_logits(params: Dict, images: jnp.ndarray, cfg: ViTConfig,
               ) -> jnp.ndarray:
    z = vit_forward(params, images, cfg)
    h = params["head"]
    cls = layers.layer_norm(z[:, 0, :], h["ln_g"], h["ln_b"])
    return cls @ h["w_head"] + h["b_head"]
