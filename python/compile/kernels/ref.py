"""Pure-jnp oracles for the Pallas kernels (correctness contract).

Every kernel in this package must match its reference here to float32
tolerance; pytest + hypothesis enforce this across shapes and dtypes.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sbmm_ref(x: jnp.ndarray, w: jnp.ndarray, element_mask: jnp.ndarray,
             ) -> jnp.ndarray:
    """Block-sparse matmul reference: Y = X (W . M)."""
    return x @ (w * element_mask)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-head attention reference.

    q, k, v: (B, H, N, D'). Returns (out (B, H, N, D'),
    cls_attn (B, H, N)) where cls_attn is the CLS row of the attention
    matrix (input to token importance scoring).
    """
    d = q.shape[-1]
    logits = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(
        jnp.asarray(d, q.dtype))
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhnm,bhmd->bhnd", attn, v)
    return out, attn[:, :, 0, :]


def fuse_ref(tokens: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Weighted token fusion reference.

    tokens: (B, N, D); weights: (B, N) (zero for retained tokens).
    Returns (B, D): sum_i w_i t_i / (sum_i w_i + eps).
    """
    denom = jnp.sum(weights, axis=1, keepdims=True) + 1e-6
    return jnp.einsum("bn,bnd->bd", weights, tokens) / denom
