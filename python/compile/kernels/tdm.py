"""Token-fusion kernel for the Token Dropping Module (Pallas).

The TDHM's final stage fuses all inattentive tokens into one token by
score-weighted aggregation (Section V-C3). On TPU the sorting network is
replaced by lax.top_k (DESIGN.md §Hardware-Adaptation); the fusion
reduction is the part worth a kernel: a single VMEM pass over the token
matrix accumulating w_i * t_i and w_i simultaneously.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fuse_kernel(tok_ref, w_ref, o_ref):
    tokens = tok_ref[0]                                # (N, D)
    w = w_ref[0]                                       # (N,)
    num = jnp.dot(w[None, :], tokens,
                  preferred_element_type=jnp.float32)  # (1, D)
    denom = jnp.sum(w) + 1e-6
    o_ref[0] = (num[0] / denom).astype(o_ref.dtype)


def fuse_tokens(tokens: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, N, D); weights: (B, N) -> fused (B, D)."""
    bsz, n, d = tokens.shape
    return pl.pallas_call(
        _fuse_kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, d), tokens.dtype),
        interpret=True,
    )(tokens, weights)
