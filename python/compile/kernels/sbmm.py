"""Sparse Block-wise Matrix Multiplication (SBMM) as a Pallas kernel.

This is the TPU re-thinking of the paper's MPCA SBMM datapath
(Algorithm 2 + Fig. 5/8). The FPGA stores a pruned weight matrix
column-major with a per-column *header* listing the row indices of
retained b x b blocks; PEs walk the header and gather the matching input
blocks. Here:

  * the packed representation (`pack_blocks`) is exactly the Fig. 5
    layout: per column-of-blocks, a dense array of surviving blocks plus
    an index header (padded to the max column population);
  * the Pallas grid walks (input row-block, weight column-block) — the
    p_t x p_c PE tiling — and the kernel's fori_loop plays the header
    walk, gathering input blocks from VMEM (the Global Feature Buffer)
    with dynamic slices;
  * the MXU analogue of the p_pe x p_pe PE array is the b x b block
    matmul inside the loop.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against ref.sbmm_ref and real-TPU
behaviour is estimated analytically (DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels import ref


def pack_blocks(w: jnp.ndarray, block_mask: jnp.ndarray, b: int,
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pack a block-pruned weight into the Fig. 5 column-major layout.

    w: (M2, D); block_mask: (m, n) with m=ceil(M2/b), n=ceil(D/b).
    Returns (blocks (n, max_cnt, b, b), header (n, max_cnt) int32 row
    indices padded with 0, count (n,) int32). Deterministic given the mask.
    """
    m, n = block_mask.shape
    m2, d = w.shape
    wp = jnp.zeros((m * b, n * b), w.dtype).at[:m2, :d].set(w)
    mask = jnp.asarray(block_mask) > 0
    counts = jnp.sum(mask, axis=0).astype(jnp.int32)
    max_cnt = int(jnp.max(counts)) if int(jnp.max(counts)) > 0 else 1

    blocks = jnp.zeros((n, max_cnt, b, b), w.dtype)
    header = jnp.zeros((n, max_cnt), jnp.int32)
    # Build with host loops: packing runs once, offline (Section V-A).
    mask_host = jax.device_get(mask)
    for j in range(n):
        rows = [i for i in range(m) if mask_host[i, j]]
        for t, i in enumerate(rows):
            blocks = blocks.at[j, t].set(wp[i * b:(i + 1) * b, j * b:(j + 1) * b])
            header = header.at[j, t].set(i)
    return blocks, header, counts


def _sbmm_kernel(x_ref, blocks_ref, header_ref, count_ref, o_ref, *, b: int,
                 max_cnt: int):
    """One output block Y[i, j]: walk column j's header, gather X blocks."""
    acc = jnp.zeros((b, b), jnp.float32)

    def body(t, acc):
        row_idx = header_ref[0, t]
        x_blk = x_ref[:, pl.ds(row_idx * b, b)]          # gather from GFB
        w_blk = blocks_ref[0, t]
        valid = (t < count_ref[0]).astype(jnp.float32)
        return acc + valid * jnp.dot(x_blk, w_blk,
                                     preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, max_cnt, body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def sbmm(x: jnp.ndarray, blocks: jnp.ndarray, header: jnp.ndarray,
         counts: jnp.ndarray, b: int, out_dim: int) -> jnp.ndarray:
    """Y = X @ W for block-pruned W in packed layout. x: (M1, M2)."""
    m1, m2 = x.shape
    n, max_cnt = header.shape
    rows = math.ceil(m1 / b)
    m_blocks = math.ceil(m2 / b)
    xp = jnp.zeros((rows * b, m_blocks * b), x.dtype).at[:m1, :m2].set(x)

    kernel = functools.partial(_sbmm_kernel, b=b, max_cnt=max_cnt)
    y = pl.pallas_call(
        kernel,
        grid=(rows, n),
        in_specs=[
            # X row-stripe i (the PE row's shared token blocks)
            pl.BlockSpec((b, m_blocks * b), lambda i, j: (i, 0)),
            # column j's packed blocks + header + count (the Column Buffer)
            pl.BlockSpec((1, max_cnt, b, b), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((1, max_cnt), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows * b, n * b), x.dtype),
        interpret=True,
    )(xp, blocks, header, counts)
    return y[:m1, :out_dim]


def sbmm_from_mask(x: jnp.ndarray, w: jnp.ndarray, block_mask: jnp.ndarray,
                   b: int) -> jnp.ndarray:
    """Convenience wrapper: pack + run. Matches ref.sbmm_ref."""
    blocks, header, counts = pack_blocks(w, block_mask, b)
    return sbmm(x, blocks, header, counts, b, w.shape[1])


__all__ = ["pack_blocks", "sbmm", "sbmm_from_mask", "ref"]
