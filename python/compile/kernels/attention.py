"""Fused per-head attention with on-the-fly token scoring (Pallas).

The FPGA pipeline computes A_h = softmax(Q_h K_h^T / sqrt(D')) per head via
DHBMM + the EM module and *streams the CLS attention row into the TDHM* so
token importance scores are a by-product of MSA, never a separate pass
(Section V-C3). This kernel mirrors that: one grid step per (batch, head)
computes the attention output AND emits the CLS row of A_h.

TPU mapping (DESIGN.md §Hardware-Adaptation): the (B, H) grid is the
p_h-CHM head parallelism; Q/K/V head slices live in VMEM (Column Buffer /
GFB analogues); the row-max + exp + normalize sequence is the EM datapath.
interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, cls_ref, *, scale: float):
    q = q_ref[0, 0]                                   # (N, D')
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Numerically stable softmax (the EM's exp + scaling-factor pipeline).
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    attn = e / denom
    o_ref[0, 0] = jnp.dot(attn, v,
                          preferred_element_type=jnp.float32).astype(o_ref.dtype)
    cls_ref[0, 0] = attn[0, :].astype(cls_ref.dtype)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q, k, v: (B, H, N, D') -> (out (B, H, N, D'), cls_attn (B, H, N))."""
    bsz, h, n, d = q.shape
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_attn_kernel, scale=scale)
    spec = pl.BlockSpec((1, 1, n, d), lambda b, hh: (b, hh, 0, 0))
    out, cls_attn = pl.pallas_call(
        kernel,
        grid=(bsz, h),
        in_specs=[spec, spec, spec],
        out_specs=[
            pl.BlockSpec((1, 1, n, d), lambda b, hh: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda b, hh: (b, hh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, n, d), q.dtype),
            jax.ShapeDtypeStruct((bsz, h, n), q.dtype),
        ],
        interpret=True,
    )(q, k, v)
    return out, cls_attn
