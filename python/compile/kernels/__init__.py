"""L1: Pallas kernels for the paper's compute hot-spots + jnp oracles."""

from compile.kernels import ref  # noqa: F401
from compile.kernels.sbmm import pack_blocks, sbmm, sbmm_from_mask  # noqa: F401
from compile.kernels.attention import attention as fused_attention  # noqa: F401
from compile.kernels.tdm import fuse_tokens  # noqa: F401
