"""Minimal AdamW (decoupled weight decay [46]) over arbitrary pytrees.

The image has no optax; this implements exactly what Section VI uses:
AdamW, lr 2e-5 (configurable), weight decay 0.01.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.zeros_like, params))


def adamw_update(grads, state: AdamWState, params, lr: float,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.01) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state.nu, grads)
    mu_hat_scale = 1.0 / (1 - b1 ** t)
    nu_hat_scale = 1.0 / (1 - b2 ** t)

    def upd(p, m, v):
        return p - lr * (m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + eps)
                         + weight_decay * p)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
