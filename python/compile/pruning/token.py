"""Dynamic token pruning — the Token Dropping Module (Section IV-B).

Token importance is non-parametric: the MSA attention matrix A (B, H, N, N)
is aggregated across heads, and the CLS row gives each non-CLS token an
importance score (following [28] / EViT). Given keep rate r_t,
k = ceil((N-1) * r_t) attentive tokens are retained *in score order* (the
hardware reconstructs Z_out sorted by importance via the TDHM's bitonic
sorter); the inattentive remainder is fused into a single token by
score-weighted aggregation. Output: [CLS; top-k tokens; fused] with
1 + k + 1 tokens.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def token_importance_scores(attn: jnp.ndarray) -> jnp.ndarray:
    """S = (1/H) sum_h A_h, taking the CLS row: (B, H, N, N) -> (B, N-1)."""
    return jnp.mean(attn[:, :, 0, 1:], axis=1)


def token_drop(z: jnp.ndarray, scores: jnp.ndarray, r_t: float,
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop inattentive tokens from z given per-token scores.

    z: (B, N, D) including CLS at index 0; scores: (B, N-1) for non-CLS
    tokens. Returns (z_out (B, 1+k+1, D), kept_idx (B, k) into the non-CLS
    token range).
    """
    bsz, n, d = z.shape
    k = max(1, math.ceil((n - 1) * r_t))
    top_scores, top_idx = jax.lax.top_k(scores, k)           # (B, k) desc.

    tokens = z[:, 1:, :]                                     # (B, N-1, D)
    kept = jnp.take_along_axis(tokens, top_idx[..., None], axis=1)

    # Fuse the inattentive remainder: weighted aggregation by score.
    mask = jnp.ones((bsz, n - 1), z.dtype)
    mask = mask.at[jnp.arange(bsz)[:, None], top_idx].set(0.0)
    w = scores * mask                                        # (B, N-1)
    denom = jnp.sum(w, axis=1, keepdims=True) + 1e-6
    fused = jnp.einsum("bn,bnd->bd", w, tokens) / denom      # (B, D)

    z_out = jnp.concatenate([z[:, :1, :], kept, fused[:, None, :]], axis=1)
    return z_out, top_idx


def tdm(z_prime: jnp.ndarray, attn: jnp.ndarray, r_t: float) -> jnp.ndarray:
    """TDM inserted between MSA and MLP (Fig. 4): Z' <- TDM(Z')."""
    scores = token_importance_scores(attn)
    z_out, _ = token_drop(z_prime, scores, r_t)
    return z_out
