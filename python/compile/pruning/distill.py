"""Knowledge distillation loss (Section IV-C, Eq. 9).

L_distill = T^2 * KL(p_teacher(T) || p_student(T)), computed from class
logits; the final training loss is the weighted sum
lambda_distill * L_distill + lambda_normal * L (Algorithm 1, line 15).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def distillation_loss(teacher_logits: jnp.ndarray, student_logits: jnp.ndarray,
                      temperature: float) -> jnp.ndarray:
    """T^2-scaled KL divergence between tempered softmax distributions."""
    t = temperature
    log_p_t = jax.nn.log_softmax(teacher_logits / t, axis=-1)
    log_p_s = jax.nn.log_softmax(student_logits / t, axis=-1)
    p_t = jnp.exp(log_p_t)
    kl = jnp.sum(p_t * (log_p_t - log_p_s), axis=-1)
    return (t * t) * jnp.mean(kl)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def score_penalty(scores) -> jnp.ndarray:
    """lambda-weighted ||sigma(S)|| sparsity penalty (Eq. 8), unweighted."""
    total = jnp.asarray(0.0)
    for s in jax.tree_util.tree_leaves(scores):
        total = total + jnp.sum(jax.nn.sigmoid(s))
    return total
