"""Static block-wise weight pruning (Section IV-A).

Every prunable weight matrix W in {W_q, W_k, W_v, W_proj} carries a learned
score matrix S of block granularity (b x b). A binary mask M keeps the
top-k scoring blocks (Eq. 7); the masked weight W . M is used in the
forward pass and a straight-through estimator passes gradients to S.

MSA *alternate pattern* (Fig. 2): W_{q,k,v} are pruned along the head
(column) dimension and W_proj along the head (row) dimension with the same
per-head structure, so a head whose blocks vanish from W_p also vanishes
from W_proj and is removed entirely.

MLP (Fig. 3): a single score *vector* over D_mlp prunes entire columns of
W_int and the matching rows of W_out (column/row alternate pattern), i.e.
whole neurons; alpha_mlp = r_b.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.configs import PruningConfig, ViTConfig

# Weight matrices pruned block-wise within the MSA.
MSA_WEIGHTS = ("w_qkv", "w_proj")


def block_grid(shape: Tuple[int, int], b: int) -> Tuple[int, int]:
    """Number of (b x b) blocks along each dimension, with ceil padding."""
    return (math.ceil(shape[0] / b), math.ceil(shape[1] / b))


def init_scores(key, cfg: ViTConfig, pruning: PruningConfig) -> List[Dict]:
    """Initialize per-encoder score parameters.

    Scores start at small positive values so the cubic schedule begins from
    a (nearly) dense model and sparsifies smoothly.
    """
    b = pruning.block_size
    scores = []
    for i in range(cfg.num_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        s_qkv = 0.01 * jax.random.normal(k1, block_grid((cfg.dim, 3 * cfg.qkv_dim), b))
        s_proj = 0.01 * jax.random.normal(k2, block_grid((cfg.qkv_dim, cfg.dim), b))
        s_mlp = 0.01 * jax.random.normal(k3, (cfg.mlp_dim,))
        scores.append({"w_qkv": s_qkv, "w_proj": s_proj, "mlp": s_mlp})
    return scores


def block_topk_mask(s: jnp.ndarray, keep_rate: float) -> jnp.ndarray:
    """Binary mask over a block-score matrix keeping the top-k blocks (Eq. 7)."""
    k = max(1, int(round(keep_rate * s.size)))
    flat = s.reshape(-1)
    if k >= flat.shape[0]:
        return jnp.ones_like(s)
    threshold = jax.lax.top_k(flat, k)[0][-1]
    return (s >= threshold).astype(s.dtype).reshape(s.shape)


def vector_topk_mask(s: jnp.ndarray, keep_rate: float) -> jnp.ndarray:
    """Binary mask over a score vector keeping the top-k entries."""
    return block_topk_mask(s, keep_rate)


def block_mask_to_element_mask(mask_blocks: jnp.ndarray, shape: Tuple[int, int],
                               b: int) -> jnp.ndarray:
    """Expand an (m, n) block mask to an (M1, M2) element mask."""
    m1, m2 = shape
    expanded = jnp.kron(mask_blocks, jnp.ones((b, b), mask_blocks.dtype))
    return expanded[:m1, :m2]


def masks_from_scores(scores: List[Dict], cfg: ViTConfig,
                      pruning: PruningConfig) -> List[Dict]:
    """Compute per-encoder element-level masks for all prunable weights.

    Returns a list of dicts with keys w_qkv, w_proj, w_int, w_out; each is a
    {0,1} array broadcastable onto the corresponding weight.
    """
    b = pruning.block_size
    masks = []
    for s in scores:
        mb_qkv = block_topk_mask(s["w_qkv"], pruning.r_b)
        mb_proj = block_topk_mask(s["w_proj"], pruning.r_b)
        mv_mlp = vector_topk_mask(s["mlp"], pruning.r_b)
        masks.append({
            "w_qkv": block_mask_to_element_mask(
                mb_qkv, (cfg.dim, 3 * cfg.qkv_dim), b),
            "w_proj": block_mask_to_element_mask(
                mb_proj, (cfg.qkv_dim, cfg.dim), b),
            # column mask on W_int (D, D_mlp) / row mask on W_out (D_mlp, D)
            "w_int": mv_mlp[None, :],
            "w_out": mv_mlp[:, None],
            # block masks retained for structure export / hardware sim
            "blocks_qkv": mb_qkv,
            "blocks_proj": mb_proj,
            "neurons": mv_mlp,
        })
    return masks


def apply_masks(params: Dict, masks: List[Dict], ste: bool = False) -> Dict:
    """Return params with masked MSA/MLP weights (W <- W . M).

    With ste=True the mask is applied through a straight-through estimator:
    forward sees W . M, backward sees dL/dW unmasked (the STE of Sec. IV-A
    with respect to W; gradients w.r.t. scores flow via the score penalty
    and the soft mask during training, see train.py).
    """
    new_encoders = []
    for p, m in zip(params["encoders"], masks):
        q = dict(p)
        for name in ("w_qkv", "w_proj", "w_int", "w_out"):
            w, mask = p[name], m[name]
            masked = w * mask
            if ste:
                masked = w + jax.lax.stop_gradient(masked - w)
            q[name] = masked
        # bias of pruned MLP neurons must vanish too, so the neuron is
        # genuinely removable from the hardware datapath.
        q["b_int"] = p["b_int"] * m["neurons"]
        new_encoders.append(q)
    return {**params, "encoders": new_encoders}


# ---------------------------------------------------------------------------
# Structure queries (used for complexity accounting and hardware export)
# ---------------------------------------------------------------------------

def kept_heads(mask_blocks_qkv: jnp.ndarray, mask_blocks_proj: jnp.ndarray,
               cfg: ViTConfig, b: int) -> jnp.ndarray:
    """Boolean (H,) vector: head h is kept iff any of its blocks survive.

    The alternate pattern couples W_p columns and W_proj rows per head: a
    head is removed only when *all* of its blocks are pruned in both.
    """
    hd_blocks = max(1, cfg.head_dim // b) if cfg.head_dim >= b else 1
    heads = []
    for h in range(cfg.num_heads):
        cols = []
        for part in range(3):  # q, k, v column ranges inside w_qkv
            start = (part * cfg.num_heads + h) * cfg.head_dim
            c0 = start // b
            cols.append(mask_blocks_qkv[:, c0:c0 + hd_blocks])
        qkv_alive = jnp.any(jnp.stack([jnp.any(c > 0) for c in cols]))
        r0 = (h * cfg.head_dim) // b
        proj_alive = jnp.any(mask_blocks_proj[r0:r0 + hd_blocks, :] > 0)
        heads.append(jnp.logical_or(qkv_alive, proj_alive))
    return jnp.stack(heads)


def head_retained_ratio(masks: List[Dict], cfg: ViTConfig, b: int) -> float:
    """Average fraction of heads retained across encoders (Table VI col. 5)."""
    total = 0.0
    for m in masks:
        alive = kept_heads(m["blocks_qkv"], m["blocks_proj"], cfg, b)
        total += float(jnp.mean(alive.astype(jnp.float32)))
    return total / len(masks)


def structure_summary(masks: List[Dict], cfg: ViTConfig,
                      pruning: PruningConfig) -> List[Dict]:
    """Per-encoder sparsity structure consumed by the Rust simulator.

    For each encoder: per-column retained-block counts of w_qkv / w_proj
    (load-imbalance input), retained neuron count, kept-head bitmap.
    """
    out = []
    for m in masks:
        alive = kept_heads(m["blocks_qkv"], m["blocks_proj"], cfg,
                           pruning.block_size)
        out.append({
            "qkv_col_blocks": [int(c) for c in
                               jnp.sum(m["blocks_qkv"] > 0, axis=0).tolist()],
            "qkv_rows": int(m["blocks_qkv"].shape[0]),
            "proj_col_blocks": [int(c) for c in
                                jnp.sum(m["blocks_proj"] > 0, axis=0).tolist()],
            "proj_rows": int(m["blocks_proj"].shape[0]),
            "neurons_kept": int(jnp.sum(m["neurons"] > 0)),
            "heads_kept": [bool(x) for x in alive.tolist()],
        })
    return out
