"""Pruning algorithms: static block weight pruning, dynamic token pruning,
and the simultaneous fine-pruning trainer (Section IV)."""

from compile.pruning.block import (  # noqa: F401
    init_scores, block_topk_mask, vector_topk_mask, masks_from_scores,
    apply_masks, block_mask_to_element_mask, head_retained_ratio,
    kept_heads, structure_summary,
)
from compile.pruning.token import (  # noqa: F401
    token_importance_scores, token_drop, tdm,
)
from compile.pruning.schedule import cubic_sparsity_schedule  # noqa: F401
