"""Simultaneous Fine-Pruning (Algorithm 1).

A sparse student is trained on sparse attentive tokens: every step,

  1. block masks {M} are computed from the learned scores {S} at the
     current keep rate r_b (cubic schedule, Section VI);
  2. the forward pass uses W . M with a straight-through estimator so
     gradients reach both W and S (soft-sigmoid STE);
  3. TDM drops tokens at the configured encoder depths;
  4. the loss is lambda_distill * L_distill(teacher, student)
     + lambda_normal * (CE + lambda * ||sigma(S)||)  (Eqs. 8, 9, line 15);
  5. AdamW updates {W, S}.

Inside the jitted step the top-k mask uses a *dynamic quantile threshold*
rather than lax.top_k so the scheduled r_b can be a traced scalar (no
retrace per schedule step); the exported/inference mask path
(block.masks_from_scores) uses exact static top-k. The two agree whenever
scores are distinct — tested in python/tests.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Iterator, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from compile.configs import PruningConfig, ViTConfig
from compile.optim import AdamWState, adamw_init, adamw_update
from compile.pruning import block
from compile.pruning.distill import (cross_entropy, distillation_loss,
                                     score_penalty)
from compile.pruning.schedule import cubic_sparsity_schedule
from compile.pruned_model import pruned_vit_logits
from compile.vit.model import vit_logits


class TrainState(NamedTuple):
    params: Dict
    scores: List[Dict]
    opt_params: AdamWState
    opt_scores: AdamWState


def _quantile_mask(s: jnp.ndarray, keep_rate: jnp.ndarray,
                   tau: float) -> jnp.ndarray:
    """Soft-STE top-k mask with a dynamic threshold (traced keep_rate)."""
    flat = s.reshape(-1)
    # Full descending sort via top_k (jnp.sort/quantile hit a broken
    # gather lowering in this jax/jaxlib combination; top_k is safe).
    vals = jax.lax.top_k(flat, flat.shape[0])[0]
    # round-to-nearest keep count, matching block.block_topk_mask exactly
    keep_n = jnp.clip(jnp.round(keep_rate * flat.shape[0]).astype(jnp.int32),
                      1, flat.shape[0])
    thresh = jax.lax.stop_gradient(
        jax.lax.dynamic_index_in_dim(vals, keep_n - 1, keepdims=False))
    hard = (s >= thresh).astype(s.dtype)
    soft = jax.nn.sigmoid((s - thresh) / tau)
    return soft + jax.lax.stop_gradient(hard - soft)


def masked_params_ste(params: Dict, scores: List[Dict], keep_rate,
                      cfg: ViTConfig, pruning: PruningConfig,
                      tau: float = 0.05) -> Dict:
    """Masked weights with gradients flowing to W (STE) and S (soft STE)."""
    b = pruning.block_size
    new_encoders = []
    for p, s in zip(params["encoders"], scores):
        mb_qkv = _quantile_mask(s["w_qkv"], keep_rate, tau)
        mb_proj = _quantile_mask(s["w_proj"], keep_rate, tau)
        mv = _quantile_mask(s["mlp"], keep_rate, tau)
        q = dict(p)
        q["w_qkv"] = p["w_qkv"] * block.block_mask_to_element_mask(
            mb_qkv, p["w_qkv"].shape, b)
        q["w_proj"] = p["w_proj"] * block.block_mask_to_element_mask(
            mb_proj, p["w_proj"].shape, b)
        q["w_int"] = p["w_int"] * mv[None, :]
        q["w_out"] = p["w_out"] * mv[:, None]
        q["b_int"] = p["b_int"] * mv
        new_encoders.append(q)
    return {**params, "encoders": new_encoders}


def make_train_step(cfg: ViTConfig, pruning: PruningConfig,
                    teacher_params: Dict, lr: float = 2e-5,
                    weight_decay: float = 0.01) -> Callable:
    """Build the jitted Algorithm-1 step: (state, batch, r_b) -> (state, aux)."""

    def loss_fn(params, scores, images, labels, keep_rate):
        mp = masked_params_ste(params, scores, keep_rate, cfg, pruning)
        student_logits = pruned_vit_logits(mp, images, cfg, pruning)
        teacher_logits = jax.lax.stop_gradient(
            vit_logits(teacher_params, images, cfg))
        ce = cross_entropy(student_logits, labels)
        dl = distillation_loss(teacher_logits, student_logits,
                               pruning.distill_temperature)
        sp = score_penalty(scores)
        generic = ce + pruning.lambda_score * sp                   # Eq. 8
        loss = (pruning.lambda_distill * dl
                + pruning.lambda_normal * generic)                 # line 15
        acc = jnp.mean((jnp.argmax(student_logits, -1) == labels)
                       .astype(jnp.float32))
        return loss, {"loss": loss, "ce": ce, "distill": dl,
                      "penalty": sp, "acc": acc}

    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(state: TrainState, images, labels, keep_rate):
        (_, aux), (g_params, g_scores) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(
                state.params, state.scores, images, labels, keep_rate)
        params, opt_p = adamw_update(g_params, state.opt_params, state.params,
                                     lr, weight_decay=weight_decay)
        # Scores take a larger LR and no weight decay (they are logits).
        scores, opt_s = adamw_update(g_scores, state.opt_scores, state.scores,
                                     lr * 100.0, weight_decay=0.0)
        return TrainState(params, scores, opt_p, opt_s), aux

    return step


def init_train_state(key, cfg: ViTConfig, pruning: PruningConfig,
                     init_params: Dict | None = None) -> TrainState:
    k1, k2 = jax.random.split(key)
    from compile.vit.params import init_vit_params
    params = init_params if init_params is not None else init_vit_params(k1, cfg)
    scores = block.init_scores(k2, cfg, pruning)
    return TrainState(params, scores, adamw_init(params), adamw_init(scores))


def train_simultaneous(state: TrainState, cfg: ViTConfig,
                       pruning: PruningConfig, teacher_params: Dict,
                       data_iter: Iterator, steps: int, lr: float = 2e-5,
                       log_every: int = 20,
                       log: Callable[[str], None] = print,
                       ) -> Tuple[TrainState, List[Dict]]:
    """Run Algorithm 1 for `steps` minibatches; returns (state, history)."""
    step_fn = make_train_step(cfg, pruning, teacher_params, lr)
    history = []
    for i in range(steps):
        r_b = cubic_sparsity_schedule(i, steps, pruning.r_b)
        images, labels = next(data_iter)
        state, aux = step_fn(state, images, labels, jnp.asarray(r_b))
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in aux.items()}
            rec.update(step=i, r_b=r_b)
            history.append(rec)
            log(f"step {i:5d} r_b={r_b:.3f} loss={rec['loss']:.4f} "
                f"ce={rec['ce']:.4f} acc={rec['acc']:.3f}")
    return state, history


# ---------------------------------------------------------------------------
# Dense baseline training (teacher) + evaluation
# ---------------------------------------------------------------------------

def make_dense_step(cfg: ViTConfig, lr: float = 1e-3) -> Callable:
    def loss_fn(params, images, labels):
        logits = vit_logits(params, images, cfg)
        ce = cross_entropy(logits, labels)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return ce, {"loss": ce, "acc": acc}

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt: AdamWState, images, labels):
        (_, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, images, labels)
        params, opt = adamw_update(grads, opt, params, lr)
        return params, opt, aux

    return step


def train_dense(params: Dict, cfg: ViTConfig, data_iter: Iterator,
                steps: int, lr: float = 1e-3, log_every: int = 20,
                log: Callable[[str], None] = print) -> Tuple[Dict, List[Dict]]:
    step_fn = make_dense_step(cfg, lr)
    opt = adamw_init(params)
    history = []
    for i in range(steps):
        images, labels = next(data_iter)
        params, opt, aux = step_fn(params, opt, images, labels)
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in aux.items()}
            rec["step"] = i
            history.append(rec)
            log(f"dense step {i:5d} loss={rec['loss']:.4f} acc={rec['acc']:.3f}")
    return params, history


def evaluate_pruned(state: TrainState, cfg: ViTConfig, pruning: PruningConfig,
                    data_iter: Iterator, batches: int = 10) -> float:
    """Accuracy of the hard-masked student (exact top-k masks)."""
    masks = block.masks_from_scores(state.scores, cfg, pruning)
    mp = block.apply_masks(state.params, masks)
    fwd = jax.jit(lambda imgs: pruned_vit_logits(mp, imgs, cfg, pruning))
    correct = total = 0
    for _ in range(batches):
        images, labels = next(data_iter)
        pred = jnp.argmax(fwd(images), -1)
        correct += int(jnp.sum(pred == labels))
        total += labels.shape[0]
    return correct / total


def evaluate_dense(params: Dict, cfg: ViTConfig, data_iter: Iterator,
                   batches: int = 10) -> float:
    fwd = jax.jit(lambda imgs: vit_logits(params, imgs, cfg))
    correct = total = 0
    for _ in range(batches):
        images, labels = next(data_iter)
        pred = jnp.argmax(fwd(images), -1)
        correct += int(jnp.sum(pred == labels))
        total += labels.shape[0]
    return correct / total
