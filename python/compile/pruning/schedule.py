"""Cubic sparsity scheduler (Section VI, following movement pruning [17]).

The keep rate r_b is scheduled from full density 1.0 down to its final
value with a warm-up phase (dense), a cubic decay, and a cool-down phase
(final density), over the training steps.
"""

from __future__ import annotations


def cubic_sparsity_schedule(step: int, total_steps: int, final_keep: float,
                            warmup_frac: float = 0.1,
                            cooldown_frac: float = 0.2) -> float:
    """Keep rate at `step`; 1.0 during warm-up, `final_keep` in cool-down."""
    if total_steps <= 0:
        return final_keep
    warmup = int(warmup_frac * total_steps)
    cooldown_start = int((1.0 - cooldown_frac) * total_steps)
    if step < warmup:
        return 1.0
    if step >= cooldown_start:
        return final_keep
    span = max(1, cooldown_start - warmup)
    t = (step - warmup) / span
    return final_keep + (1.0 - final_keep) * (1.0 - t) ** 3
