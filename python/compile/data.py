"""Synthetic patch-classification dataset (ImageNet stand-in).

DESIGN.md Substitutions: accuracy-recovery behaviour of simultaneous
pruning is a property of the training algorithm, not of ImageNet. This
dataset is constructed so the *mechanisms* the paper relies on are
exercised:

  * class evidence is localized in a small number of patches (so token
    importance varies and dynamic token pruning has signal to find);
  * the remaining patches are pure distractor noise (so inattentive-token
    fusion is nearly lossless when the model attends correctly);
  * classes are linearly non-trivial (patterns are random dense patches,
    plus per-image noise) so the model must actually train.

Each class c has a fixed random patch pattern; an image of class c places
that pattern at `signal_patches` random patch positions over a noise
background.
"""

from __future__ import annotations

from typing import Iterator, Tuple

import jax
import jax.numpy as jnp

from compile.configs import ViTConfig


def make_class_patterns(key, cfg: ViTConfig) -> jnp.ndarray:
    """(num_classes, P, P, C) fixed patterns, one per class."""
    return jax.random.normal(
        key, (cfg.num_classes, cfg.patch_size, cfg.patch_size, cfg.in_channels))


def synth_batch(key, patterns: jnp.ndarray, cfg: ViTConfig, batch: int,
                signal_patches: int = 3, noise_std: float = 0.5,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (images (B, H, W, C), labels (B,))."""
    k_lab, k_pos, k_noise = jax.random.split(key, 3)
    labels = jax.random.randint(k_lab, (batch,), 0, cfg.num_classes)
    side = cfg.image_size // cfg.patch_size
    n_patches = side * side
    # Random distinct-ish positions per image (with replacement is fine).
    pos = jax.random.randint(k_pos, (batch, signal_patches), 0, n_patches)
    noise = noise_std * jax.random.normal(
        k_noise, (batch, n_patches, cfg.patch_size, cfg.patch_size,
                  cfg.in_channels))

    sig = patterns[labels]                                   # (B, P, P, C)
    patches = noise
    batch_idx = jnp.arange(batch)[:, None]
    patches = patches.at[batch_idx, pos].add(sig[:, None])

    imgs = patches.reshape(batch, side, side, cfg.patch_size, cfg.patch_size,
                           cfg.in_channels)
    imgs = imgs.transpose(0, 1, 3, 2, 4, 5).reshape(
        batch, cfg.image_size, cfg.image_size, cfg.in_channels)
    return imgs, labels


def data_stream(seed: int, patterns: jnp.ndarray, cfg: ViTConfig,
                batch: int, **kw) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray]]:
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield synth_batch(sub, patterns, cfg, batch, **kw)
