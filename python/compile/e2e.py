"""End-to-end driver (build/training phase).

Trains the ViT on the synthetic patch-classification dataset (the
ImageNet stand-in, see DESIGN.md Substitutions):

  1. dense teacher (baseline accuracy);
  2. *naive* pruning: hard top-k masks applied post-hoc, no fine-tuning
     (the accuracy cliff the paper's Section I warns about);
  3. simultaneous fine-pruning (Algorithm 1) with distillation — the
     paper's contribution — recovering the accuracy;
  4. exports the trained pruned model through the AOT pipeline so the
     Rust coordinator can serve it (examples/e2e_train_serve.rs).

Outputs (to --out): the standard artifact set for the trained variant +
``e2e_results.json`` with loss curves and the accuracy comparison.

Usage:  python -m compile.e2e --out ../artifacts_e2e [--steps 300]
        [--sweep]   # also run the r_b x r_t accuracy sweep (Table VI proxy)
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from compile.aot import export_variant
from compile.configs import TEST_TINY, PruningConfig
from compile.data import data_stream, make_class_patterns
from compile.pruning import apply_masks, masks_from_scores
from compile.pruning.train import (evaluate_dense, evaluate_pruned,
                                   init_train_state, train_dense,
                                   train_simultaneous)
from compile.vit.params import init_vit_params


def run_setting(cfg, pruning, teacher, data_it, eval_it, steps, lr):
    """Algorithm-1 training for one pruning setting; returns results."""
    state = init_train_state(jax.random.PRNGKey(1), cfg, pruning,
                             init_params=teacher)
    t0 = time.time()
    state, history = train_simultaneous(
        state, cfg, pruning, teacher, data_it, steps, lr=lr,
        log_every=max(1, steps // 10))
    train_s = time.time() - t0
    acc = evaluate_pruned(state, cfg, pruning, eval_it, batches=10)
    return state, history, acc, train_s


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts_e2e")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--teacher-steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--sweep", action="store_true",
                    help="also sweep r_b x r_t for the accuracy-shape proxy")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = TEST_TINY
    # Aggressive setting (the paper's hardest: r_b = r_t = 0.5) on a
    # noisier dataset so naive post-hoc pruning visibly collapses and
    # Algorithm 1 has real accuracy to recover.
    pruning = PruningConfig(block_size=8, r_b=0.5, r_t=0.5, tdm_layers=(1, 2))
    data_kw = dict(signal_patches=2, noise_std=1.2)
    patterns = make_class_patterns(jax.random.PRNGKey(10), cfg)
    train_it = data_stream(0, patterns, cfg, args.batch, **data_kw)
    eval_it = data_stream(999, patterns, cfg, args.batch, **data_kw)

    results = {"config": cfg.name, "steps": args.steps,
               "setting": {"b": pruning.block_size, "r_b": pruning.r_b,
                           "r_t": pruning.r_t}}

    # --- 1. dense teacher -------------------------------------------------
    print("[e2e] training dense teacher ...")
    teacher = init_vit_params(jax.random.PRNGKey(0), cfg)
    teacher, dense_hist = train_dense(teacher, cfg, train_it,
                                      args.teacher_steps, lr=1e-3,
                                      log_every=max(1, args.teacher_steps // 5))
    dense_acc = evaluate_dense(teacher, cfg, eval_it, batches=10)
    print(f"[e2e] dense accuracy: {dense_acc:.3f}")
    results["dense_accuracy"] = dense_acc
    results["dense_loss_curve"] = dense_hist

    # --- 2. naive post-hoc pruning (no fine-tuning) -----------------------
    from compile.pruning.block import init_scores
    naive_scores = init_scores(jax.random.PRNGKey(2), cfg, pruning)
    naive_masks = masks_from_scores(naive_scores, cfg, pruning)
    naive_params = apply_masks(teacher, naive_masks)
    from compile.pruned_model import pruned_vit_logits
    fwd = jax.jit(lambda imgs: pruned_vit_logits(naive_params, imgs, cfg, pruning))
    correct = total = 0
    for _ in range(10):
        imgs, labels = next(eval_it)
        pred = jnp.argmax(fwd(imgs), -1)
        correct += int(jnp.sum(pred == labels))
        total += labels.shape[0]
    naive_acc = correct / total
    print(f"[e2e] naive post-hoc pruning accuracy: {naive_acc:.3f}")
    results["naive_pruned_accuracy"] = naive_acc

    # --- 3. simultaneous fine-pruning (Algorithm 1) ------------------------
    print("[e2e] simultaneous fine-pruning (Algorithm 1) ...")
    state, hist, simul_acc, train_s = run_setting(
        cfg, pruning, teacher, train_it, eval_it, args.steps, lr=5e-4)
    print(f"[e2e] simultaneous-pruned accuracy: {simul_acc:.3f} "
          f"(dense {dense_acc:.3f}, naive {naive_acc:.3f}) [{train_s:.0f}s]")
    results["simultaneous_accuracy"] = simul_acc
    results["simultaneous_loss_curve"] = hist
    results["train_seconds"] = train_s

    # --- 4. export the trained model for the Rust coordinator -------------
    print("[e2e] exporting trained artifacts ...")
    masks = masks_from_scores(state.scores, cfg, pruning)
    trained = apply_masks(state.params, masks)
    entries = []
    for batch in (1, 4):
        entries.append(export_variant(args.out, cfg, pruning, batch, False,
                                      params=trained, scores=state.scores))
    manifest = {"seed": 1234, "variants": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # --- 5. optional accuracy sweep (Table VI accuracy-column proxy) ------
    if args.sweep:
        sweep = []
        for r_b in (0.5, 0.7):
            for r_t in (0.5, 0.9):
                pr = PruningConfig(block_size=8, r_b=r_b, r_t=r_t,
                                   tdm_layers=(1, 2))
                _, _, acc, secs = run_setting(
                    cfg, pr, teacher, train_it, eval_it,
                    max(100, args.steps // 2), lr=5e-4)
                print(f"[e2e] sweep r_b={r_b} r_t={r_t}: acc={acc:.3f} [{secs:.0f}s]")
                sweep.append({"r_b": r_b, "r_t": r_t, "accuracy": acc})
        results["accuracy_sweep"] = sweep

    with open(os.path.join(args.out, "e2e_results.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(f"[e2e] wrote {args.out}/e2e_results.json")


if __name__ == "__main__":
    main()
