"""L2 façade — re-exports the model entrypoints used by aot.py and tests."""

from compile.vit.model import vit_forward, vit_logits  # noqa: F401
from compile.pruned_model import pruned_vit_logits, pruned_encoder  # noqa: F401
