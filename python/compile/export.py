"""Binary weight export + structure export for the Rust runtime.

Weight file format (little-endian), read by rust/src/runtime/weights.rs:

    magic   8 bytes  b"VITW0001"
    count   u32
    per tensor:
        name_len u32, name bytes (utf-8)
        ndim u32, dims u32 * ndim
        byte_len u64, data (f32 little-endian)

The tensor order is exactly vit.params.param_order — the same positional
order the HLO artifact's parameters use (parameter 0 is the image batch;
parameters 1.. are the weights).
"""

from __future__ import annotations

import json
import struct
from typing import Dict, List

import jax
import numpy as np

from compile.configs import PruningConfig, ViTConfig
from compile.vit.params import flatten_params, param_order

MAGIC = b"VITW0001"


def write_weights(path: str, params: Dict, cfg: ViTConfig) -> int:
    """Write flattened params; returns number of tensors written."""
    flat = flatten_params(params, cfg)
    names = ["/".join(p) for p in param_order(cfg)]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(flat)))
        for name, arr in zip(names, flat):
            a = np.asarray(jax.device_get(arr), dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", a.ndim))
            for d in a.shape:
                f.write(struct.pack("<I", d))
            data = a.tobytes()
            f.write(struct.pack("<Q", len(data)))
            f.write(data)
    return len(flat)


def read_weights(path: str) -> List:
    """Python-side reader (round-trip tests)."""
    out = []
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (blen,) = struct.unpack("<Q", f.read(8))
            data = np.frombuffer(f.read(blen), dtype=np.float32).reshape(dims)
            out.append((name, data))
    return out


def write_structure(path: str, structure: List[Dict], cfg: ViTConfig,
                    pruning: PruningConfig) -> None:
    """Per-encoder sparsity structure for the hardware simulator."""
    doc = {
        "model": cfg.name,
        "block_size": pruning.block_size,
        "r_b": pruning.r_b,
        "r_t": pruning.r_t,
        "tdm_layers": list(pruning.tdm_layers),
        "tokens_per_layer": list(
            pruning.tokens_per_layer(cfg.num_tokens, cfg.num_layers)),
        "encoders": structure,
        "dims": {
            "num_layers": cfg.num_layers, "num_heads": cfg.num_heads,
            "dim": cfg.dim, "head_dim": cfg.head_dim, "mlp_dim": cfg.mlp_dim,
            "num_tokens": cfg.num_tokens, "patch_dim": cfg.patch_dim,
            "num_classes": cfg.num_classes,
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
