"""AOT pipeline: lower pruned-ViT variants to HLO text + weights + manifest.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts per variant (written to --out, default ../artifacts):

    <name>.hlo.txt        HLO text; parameter 0 = image batch (B,H,W,C),
                          parameters 1.. = weights in param_order.
    <name>.weights.bin    masked weights (VITW0001 format).
    <name>.structure.json per-encoder sparsity structure for the simulator.
    manifest.json         index of all variants.

Python runs ONCE at build time; the Rust binary is self-contained after
`make artifacts`.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.configs import (PruningConfig, ViTConfig, model_by_name,
                             paper_table6_settings)
from compile.export import write_structure, write_weights
from compile.pruned_model import pruned_vit_logits
from compile.pruning import (apply_masks, init_scores, masks_from_scores,
                             structure_summary)
from compile.vit.params import (flatten_params, init_vit_params,
                                unflatten_params)

SEED = 1234  # deterministic artifacts


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def variant_name(cfg: ViTConfig, pruning: PruningConfig, batch: int,
                 use_kernels: bool) -> str:
    tag = (f"{cfg.name}_b{pruning.block_size}_rb{pruning.r_b:g}"
           f"_rt{pruning.r_t:g}_bs{batch}")
    return tag + ("_kernels" if use_kernels else "")


def lower_variant(cfg: ViTConfig, pruning: PruningConfig, batch: int,
                  use_kernels: bool, params: Optional[Dict] = None,
                  scores: Optional[List[Dict]] = None) -> Dict:
    """Build masked params + lowered HLO for one variant.

    Returns dict with keys: name, hlo, params (masked), structure, masks.
    """
    key = jax.random.PRNGKey(SEED)
    if params is None:
        params = init_vit_params(key, cfg)
    if scores is None:
        scores = init_scores(jax.random.PRNGKey(SEED + 1), cfg, pruning)
    masks = masks_from_scores(scores, cfg, pruning)
    masked = apply_masks(params, masks)
    structure = structure_summary(masks, cfg, pruning)

    def fn(images, *flat):
        p = unflatten_params(list(flat), cfg)
        return (pruned_vit_logits(p, images, cfg, pruning,
                                  use_kernels=use_kernels),)

    img_spec = jax.ShapeDtypeStruct(
        (batch, cfg.image_size, cfg.image_size, cfg.in_channels), jnp.float32)
    flat = flatten_params(masked, cfg)
    specs = [jax.ShapeDtypeStruct(x.shape, jnp.float32) for x in flat]
    lowered = jax.jit(fn).lower(img_spec, *specs)
    return {
        "name": variant_name(cfg, pruning, batch, use_kernels),
        "hlo": to_hlo_text(lowered),
        "params": masked,
        "structure": structure,
        "masks": masks,
    }


def export_variant(out_dir: str, cfg: ViTConfig, pruning: PruningConfig,
                   batch: int, use_kernels: bool,
                   params: Optional[Dict] = None,
                   scores: Optional[List[Dict]] = None) -> Dict:
    """Lower + write all artifact files; returns the manifest entry."""
    v = lower_variant(cfg, pruning, batch, use_kernels, params, scores)
    name = v["name"]
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(v["hlo"])
    wpath = os.path.join(out_dir, f"{name}.weights.bin")
    n_tensors = write_weights(wpath, v["params"], cfg)
    spath = os.path.join(out_dir, f"{name}.structure.json")
    write_structure(spath, v["structure"], cfg, pruning)
    # Numerics self-check: evaluate the lowered computation in jax on a
    # deterministic input; the rust integration test replays it through
    # PJRT and must match. Stored as a 2-tensor VITW file (input, logits).
    import struct as _struct
    import numpy as _np
    key = jax.random.PRNGKey(SEED + 7)
    imgs = jax.random.normal(
        key, (batch, cfg.image_size, cfg.image_size, cfg.in_channels),
        dtype=jnp.float32)
    logits = pruned_vit_logits(v["params"], imgs, cfg, pruning,
                               use_kernels=use_kernels)
    cpath = os.path.join(out_dir, f"{name}.check.bin")
    from compile.export import MAGIC
    with open(cpath, "wb") as f:
        f.write(MAGIC)
        f.write(_struct.pack("<I", 2))
        for tname, arr in (("input", imgs), ("logits", logits)):
            a = _np.asarray(jax.device_get(arr), dtype=_np.float32)
            nb = tname.encode()
            f.write(_struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(_struct.pack("<I", a.ndim))
            for d in a.shape:
                f.write(_struct.pack("<I", d))
            data = a.tobytes()
            f.write(_struct.pack("<Q", len(data)))
            f.write(data)
    return {
        "name": name,
        "model": cfg.name,
        "batch": batch,
        "use_kernels": use_kernels,
        "pruning": {
            "block_size": pruning.block_size, "r_b": pruning.r_b,
            "r_t": pruning.r_t, "tdm_layers": list(pruning.tdm_layers),
        },
        "files": {
            "hlo": os.path.basename(hlo_path),
            "weights": os.path.basename(wpath),
            "structure": os.path.basename(spath),
            "check": os.path.basename(cpath),
        },
        "num_weight_tensors": n_tensors,
        "input_shape": [batch, cfg.image_size, cfg.image_size,
                        cfg.in_channels],
        "num_classes": cfg.num_classes,
        "hlo_sha256": hashlib.sha256(v["hlo"].encode()).hexdigest()[:16],
    }


def default_variants(full: bool) -> List:
    """(model, pruning, batch, use_kernels) tuples to build by default."""
    tiny = model_by_name("test-tiny")
    small = model_by_name("deit-small")
    tiny_pr = PruningConfig(block_size=8, r_b=0.7, r_t=0.7, tdm_layers=(1, 2))
    tiny_base = PruningConfig(block_size=8, r_b=1.0, r_t=1.0)
    out = [
        (tiny, tiny_base, 1, False),
        (tiny, tiny_pr, 1, False),
        (tiny, tiny_pr, 1, True),       # kernel-correctness artifact
        (tiny, tiny_pr, 4, False),
    ]
    # DeiT-Small: baseline + the most/least aggressive Table VI settings.
    out += [
        (small, PruningConfig(block_size=16, r_b=1.0, r_t=1.0), 1, False),
        (small, PruningConfig(block_size=16, r_b=0.5, r_t=0.5), 1, False),
        (small, PruningConfig(block_size=16, r_b=0.7, r_t=0.9), 1, False),
    ]
    if full:
        for pr in paper_table6_settings():
            out.append((small, pr, 1, False))
        out.append((small, PruningConfig(block_size=16, r_b=0.7, r_t=0.7),
                    1, True))
        out.append((small, PruningConfig(block_size=16, r_b=0.5, r_t=0.5),
                    8, False))
    seen, uniq = set(), []
    for cfg, pr, bs, uk in out:
        nm = variant_name(cfg, pr, bs, uk)
        if nm not in seen:
            seen.add(nm)
            uniq.append((cfg, pr, bs, uk))
    return uniq


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also lower every Table VI setting")
    ap.add_argument("--only", default=None,
                    help="substring filter on variant names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for cfg, pruning, batch, use_kernels in default_variants(args.full):
        name = variant_name(cfg, pruning, batch, use_kernels)
        if args.only and args.only not in name:
            continue
        print(f"[aot] lowering {name} ...", flush=True)
        entries.append(export_variant(args.out, cfg, pruning, batch,
                                      use_kernels))
        print(f"[aot]   wrote {entries[-1]['files']['hlo']} "
              f"({entries[-1]['num_weight_tensors']} tensors)")

    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"seed": SEED, "variants": entries}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {manifest_path} ({len(entries)} variants)")


if __name__ == "__main__":
    main()
