"""L2 entrypoint: the *pruned* ViT forward (weight masks + TDM).

This is the computation that gets AOT-lowered to HLO and executed by the
Rust coordinator. Two equivalent compute paths exist:

  * ``use_kernels=False`` — masked-dense jnp ops; XLA fuses these into its
    native dot/softmax pipeline. This is the fast artifact used on the
    serving hot path.
  * ``use_kernels=True``  — MSA attention runs through the fused Pallas
    attention kernel (attention + CLS-row scoring in one pass) and TDM
    fusion through the Pallas fusion kernel, mirroring the FPGA's
    EM/TDHM datapath. Used for the kernel-correctness artifact.

Both are validated against each other and against the dense reference in
python/tests; the Rust integration test checks the HLO round-trip gives
identical numerics.

Shapes are fully static: given keep rate r_t, every TDM retains
k = ceil((N-1) * r_t) tokens, so each pruning setting lowers to one HLO
artifact (DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from compile.configs import PruningConfig, ViTConfig
from compile.vit import layers
from compile.kernels import attention as attn_kernel
from compile.kernels import tdm as tdm_kernel


def _msa(z: jnp.ndarray, p: Dict, cfg: ViTConfig, use_kernels: bool,
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MSA returning (out, cls_attn (B, H, N)) for token scoring."""
    b, n, _ = z.shape
    nh, hd = cfg.num_heads, cfg.head_dim
    qkv = z @ p["w_qkv"] + p["b_qkv"]
    qkv = qkv.reshape(b, n, 3, nh, hd)
    q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))
    if use_kernels:
        sa, cls_attn = attn_kernel.attention(q, k, v)
    else:
        attn = layers.attention_scores(q, k, hd)
        sa = jnp.einsum("bhnm,bhmd->bhnd", attn, v)
        cls_attn = attn[:, :, 0, :]
    sa = sa.transpose(0, 2, 1, 3).reshape(b, n, nh * hd)
    out = sa @ p["w_proj"] + p["b_proj"]
    return out, cls_attn


def _topk_selection(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """(B, k, N) one-hot selection of the top-k scores, descending order.

    Built from iterative argmax + one-hot instead of lax.top_k /
    gather/scatter: jax >= 0.8 lowers those to the `topk` HLO op and to
    gathers with `operand_batching_dims`, neither of which the
    xla_extension 0.5.1 HLO *text parser* accepts. argmax (reduce),
    one_hot (iota+eq) and dynamic_update_slice round-trip cleanly. This
    is also the closer mirror of the TDHM: the sorted one-hot rows ARE
    the (id_old -> id_new) routing table of the index shuffle network.
    """
    b, n = scores.shape

    def body(i, state):
        s, sel = state
        idx = jnp.argmax(s, axis=-1)                          # (B,)
        oh = jax.nn.one_hot(idx, n, dtype=scores.dtype)       # (B, N)
        sel = jax.lax.dynamic_update_slice_in_dim(
            sel, oh[:, None, :], i, axis=1)
        s = s - oh * 1e9                                       # knock out
        return s, sel

    sel0 = jnp.zeros((b, k, n), scores.dtype)
    _, sel = jax.lax.fori_loop(0, k, body, (scores, sel0))
    return sel


def _tdm(z: jnp.ndarray, cls_attn: jnp.ndarray, r_t: float,
         use_kernels: bool) -> jnp.ndarray:
    """Token Dropping Module on Z' given the MSA's CLS attention rows."""
    _, n, _ = z.shape
    scores = jnp.mean(cls_attn[:, :, 1:], axis=1)            # (B, N-1)
    k = max(1, math.ceil((n - 1) * r_t))
    tokens = z[:, 1:, :]
    sel = _topk_selection(scores, k)                         # (B, k, N-1)
    kept = jnp.einsum("bkn,bnd->bkd", sel, tokens)
    keep_mask = jnp.sum(sel, axis=1)                         # (B, N-1) in {0,1}
    w = scores * (1.0 - keep_mask)
    if use_kernels:
        fused = tdm_kernel.fuse_tokens(tokens, w)
    else:
        denom = jnp.sum(w, axis=1, keepdims=True) + 1e-6
        fused = jnp.einsum("bn,bnd->bd", w, tokens) / denom
    return jnp.concatenate([z[:, :1, :], kept, fused[:, None, :]], axis=1)


def pruned_encoder(z: jnp.ndarray, p: Dict, cfg: ViTConfig,
                   r_t: Optional[float], use_kernels: bool) -> jnp.ndarray:
    """Encoder with optional TDM between MSA and MLP (Fig. 4)."""
    zn = layers.layer_norm(z, p["ln1_g"], p["ln1_b"])
    att_out, cls_attn = _msa(zn, p, cfg, use_kernels)
    z_prime = att_out + z
    if r_t is not None and r_t < 1.0:
        z_prime = _tdm(z_prime, cls_attn, r_t, use_kernels)
    zn2 = layers.layer_norm(z_prime, p["ln2_g"], p["ln2_b"])
    return layers.mlp(zn2, p) + z_prime


def pruned_vit_logits(params: Dict, images: jnp.ndarray, cfg: ViTConfig,
                      pruning: PruningConfig,
                      use_kernels: bool = False) -> jnp.ndarray:
    """Full pruned forward. `params` must already carry masked weights
    (apply_masks) — at AOT time the masked weights are baked into the
    exported weight file, so the artifact takes them as plain parameters.
    """
    z = layers.patch_embed(images, params["embed"], cfg.patch_size)
    cls = jnp.broadcast_to(params["embed"]["cls"],
                           (z.shape[0], 1, cfg.dim)).astype(z.dtype)
    z = jnp.concatenate([cls, z], axis=1) + params["embed"]["pos"]
    for i, p in enumerate(params["encoders"]):
        r_t = pruning.r_t if i in pruning.tdm_layers else None
        z = pruned_encoder(z, p, cfg, r_t, use_kernels)
    h = params["head"]
    cls_tok = layers.layer_norm(z[:, 0, :], h["ln_g"], h["ln_b"])
    return cls_tok @ h["w_head"] + h["b_head"]
